"""Optimizer, quantization, gradient-compression and pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.data.pipeline import PipelineConfig, Prefetcher, TokenStream
from repro.train.compress import (ErrorFeedbackState, compress_decompress,
                                  compressed_psum, ef_compress_step)
from repro.train.optim import (AdamWConfig, adamw_init, adamw_update,
                               dequantize_blockwise, quantize_blockwise)


# ------------------------------------------------------------- quantizer ---

@given(st.integers(0, 2**30), st.sampled_from([(8,), (3, 128), (4, 7, 32)]))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_error_bound(seed, shape):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 10
    qd = quantize_blockwise(x)
    back = dequantize_blockwise(qd, shape)
    # row-wise linear int8: error ≤ scale/2 = max|row|/254 per row
    err = jnp.abs(back - x)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    assert bool((err <= bound * 0.51 + 1e-9).all())


def test_quantize_preserves_zeros():
    z = jnp.zeros((4, 16))
    back = dequantize_blockwise(quantize_blockwise(z), z.shape)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


# ----------------------------------------------------------------- AdamW ---

def _rosenbrock_params():
    return {"w": jnp.asarray([-1.2, 1.0, 0.5, 2.0]),
            "b": jnp.zeros((2, 8))}


def _loss(params):
    w = params["w"]
    return jnp.sum(100.0 * (w[1:] - w[:-1] ** 2) ** 2 + (1 - w[:-1]) ** 2) \
        + jnp.sum(params["b"] ** 2)


@pytest.mark.parametrize("bits8", [False, True])
def test_adamw_descends(bits8):
    cfg = AdamWConfig(lr=3e-2, weight_decay=0.0, bits8=bits8)
    params = _rosenbrock_params()
    state = adamw_init(params, cfg)
    l0 = float(_loss(params))
    for _ in range(200):
        grads = jax.grad(_loss)(params)
        params, state, gnorm = adamw_update(grads, state, params, cfg)
    l1 = float(_loss(params))
    assert l1 < l0 * 0.05
    assert np.isfinite(float(gnorm))


def test_adamw_8bit_tracks_fp32():
    """8-bit moments follow the f32 trajectory closely on a quadratic."""
    cfg32 = AdamWConfig(lr=1e-2, weight_decay=0.0, bits8=False)
    cfg8 = AdamWConfig(lr=1e-2, weight_decay=0.0, bits8=True)
    p32 = {"w": jnp.asarray(np.linspace(-2, 2, 32).reshape(2, 16))}
    p8 = jax.tree.map(jnp.copy, p32)
    s32, s8 = adamw_init(p32, cfg32), adamw_init(p8, cfg8)
    loss = lambda p: jnp.sum((p["w"] - 3.0) ** 2)
    for _ in range(60):
        p32, s32, _ = adamw_update(jax.grad(loss)(p32), s32, p32, cfg32)
        p8, s8, _ = adamw_update(jax.grad(loss)(p8), s8, p8, cfg8)
    d = float(jnp.abs(p32["w"] - p8["w"]).max())
    assert d < 0.05, d


def test_grad_clip_limits_update():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e9)}
    _, _, gnorm = adamw_update(huge, state, params, cfg)
    assert float(gnorm) == pytest.approx(2e9, rel=1e-5)


# ----------------------------------------------------- grad compression ----

def test_error_feedback_is_unbiased_over_time():
    """Sum of transmitted grads ≈ sum of true grads (error feedback)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(4, 64)) * (10.0 ** rng.integers(-3, 2)))
              for _ in range(50)]
    err = jnp.zeros((4, 64))
    sent_total = jnp.zeros((4, 64))
    true_total = jnp.zeros((4, 64))
    for g in g_true:
        sent, err = ef_compress_step(g, err)
        sent_total += sent
        true_total += g
    resid = float(jnp.abs(sent_total - true_total).max())
    # residual equals the final carried error — bounded by one quant step
    assert resid == pytest.approx(float(jnp.abs(err).max()), abs=1e-5)


def test_compression_convergence_matches_uncompressed():
    loss = lambda w: jnp.sum((w - 1.5) ** 2)
    w_c = jnp.zeros((8, 128))
    w_u = jnp.zeros((8, 128))
    err = jnp.zeros_like(w_c)
    for _ in range(150):
        g = jax.grad(loss)(w_c)
        sent, err = ef_compress_step(g, err)
        w_c = w_c - 0.05 * sent
        w_u = w_u - 0.05 * jax.grad(loss)(w_u)
    assert float(loss(w_c)) < 1e-3
    assert abs(float(loss(w_c)) - float(loss(w_u))) < 1e-3


def test_compressed_psum_shard_map():
    """int8 wire mean over an axis (shard_map on the host platform)."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run via subprocess suite)")
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((jax.device_count(),), ("pod",))
    from jax.experimental.shard_map import shard_map
    x = jnp.arange(jax.device_count() * 128, dtype=jnp.float32).reshape(
        jax.device_count(), 128)
    f = shard_map(lambda g: compressed_psum(g[0], "pod")[None],
                  mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None))
    out = f(x)
    expect = jnp.mean(x, axis=0)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect),
                               rtol=0.02, atol=0.5)


# ---------------------------------------------------------- data pipeline --

def test_stream_deterministic_and_resumable():
    cfg = PipelineConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    s1 = TokenStream(cfg)
    b1 = [s1.next_batch() for _ in range(3)]
    state = s1.state()
    b_next = s1.next_batch()
    s2 = TokenStream.from_state(cfg, state)
    b_resumed = s2.next_batch()
    np.testing.assert_array_equal(b_next["tokens"], b_resumed["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["labels"][:, :-1],
                                  b1[0]["tokens"][:, 1:])


def test_stream_dq_masks_corrupted_rows():
    cfg = PipelineConfig(vocab=100, seq_len=64, global_batch=64, seed=1,
                         dq_fraction=1.0, dq_missing_rate=0.5)
    batch = TokenStream(cfg).next_batch()
    assert "loss_mask" in batch
    assert batch["loss_mask"].shape == batch["labels"].shape
    assert 0.0 < batch["loss_mask"].mean() < 1.0  # some rows masked out
    assert (batch["tokens"] >= 0).all()  # sentinels replaced


def test_prefetcher_yields_same_stream():
    cfg = PipelineConfig(vocab=50, seq_len=8, global_batch=2, seed=3)
    ref_stream = TokenStream(cfg)
    direct = [ref_stream.next_batch() for _ in range(3)]
    pf = Prefetcher(TokenStream(cfg))
    try:
        fetched = [pf.next() for _ in range(3)]
    finally:
        pf.close()
    for a, b in zip(direct, fetched):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
