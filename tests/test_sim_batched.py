"""Property tests: the batched what-if evaluator (repro.sim.batched) against
the float64 numpy oracle (repro.core.costmodel), plus the Pallas edge-latency
kernel and the one-dispatch grid contract."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.core import (
    CostConfig,
    ExplicitFleet,
    RegionFleet,
    edge_latencies,
    latency,
    objective_F,
    random_dag,
    random_placement,
)
from repro.sim import BatchedEvaluator, pack_fleets, pack_placements

SETTINGS = dict(max_examples=25, deadline=None)
REL = 1e-5


def _random_fleets(rng, n_dev, n_fleets):
    fleets = []
    for k in range(n_fleets):
        if k % 2 == 0:
            com = rng.uniform(0.1, 3.0, (n_dev, n_dev))
            com = (com + com.T) / 2
            np.fill_diagonal(com, 0.0)
            fleets.append(ExplicitFleet(com_cost=com))
        else:
            n_regions = int(rng.integers(1, n_dev + 1))
            inter = rng.uniform(0.1, 2.0, (n_regions, n_regions))
            inter = (inter + inter.T) / 2
            fleets.append(RegionFleet(
                region=rng.integers(0, n_regions, n_dev), inter=inter))
    return fleets


@st.composite
def instances(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    alpha = draw(st.sampled_from([0.0, 0.25, 1.0]))
    rng = np.random.default_rng(seed)
    n_ops = int(rng.integers(2, 8))
    n_dev = int(rng.integers(2, 7))
    g = random_dag(n_ops, edge_prob=0.5, rng=rng)
    fleets = _random_fleets(rng, n_dev, int(rng.integers(1, 4)))
    xs = [random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng,
                           sparsity=float(rng.uniform(0.0, 0.7)))
          for _ in range(int(rng.integers(1, 5)))]
    return g, fleets, xs, CostConfig(alpha=alpha), rng


@given(instances())
@settings(**SETTINGS)
def test_batched_matches_oracle(inst):
    """edge_latencies / latency / objective_F: batched == numpy oracle to
    ≤1e-5 relative, over ExplicitFleet AND RegionFleet, alpha 0 and >0."""
    g, fleets, xs, cfg, _ = inst
    ev = BatchedEvaluator(g, cfg)
    coms = pack_fleets(fleets)
    P = pack_placements(xs)
    beta, dq = 0.7, 0.3
    grid = np.asarray(ev.score_grid(P, coms, dq=dq, beta=beta))
    assert grid.shape == (len(fleets), len(xs))
    for si, fleet in enumerate(fleets):
        for pi, x in enumerate(xs):
            want = objective_F(latency(g, fleet, x, cfg), dq, beta)
            assert grid[si, pi] == pytest.approx(want, rel=REL, abs=1e-6)
    # per-edge agreement on the first placement across every fleet
    b = len(fleets)
    xb = np.stack([xs[0]] * b)
    el = np.asarray(ev.edge_latencies(xb, coms))
    lat = np.asarray(ev.latency(xb, coms))
    for si, fleet in enumerate(fleets):
        np.testing.assert_allclose(
            el[si], edge_latencies(g, fleet, xs[0], cfg), rtol=REL, atol=1e-6)
        assert lat[si] == pytest.approx(latency(g, fleet, xs[0], cfg),
                                        rel=REL, abs=1e-6)


@given(instances())
@settings(max_examples=10, deadline=None)
def test_pallas_path_matches_jnp_path(inst):
    """use_pallas=True (interpret) produces the same grid as the jnp path."""
    g, fleets, xs, cfg, _ = inst
    coms = pack_fleets(fleets)
    P = pack_placements(xs)
    a = np.asarray(BatchedEvaluator(g, cfg).score_grid(P, coms, beta=0.5,
                                                       dq=0.5))
    b = np.asarray(BatchedEvaluator(g, cfg, use_pallas=True, interpret=True)
                   .score_grid(P, coms, beta=0.5, dq=0.5))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_pallas_kernel_against_ref():
    """The raw kernel against its jnp oracle over odd shapes."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    for B, E, V in [(1, 1, 2), (3, 7, 5), (2, 128, 16), (4, 33, 12)]:
        xi = jnp.asarray(rng.random((B, E, V)), jnp.float32)
        xj = jnp.asarray(rng.random((B, E, V)), jnp.float32)
        com = jnp.asarray(rng.random((B, V, V)), jnp.float32)
        out = ops.edge_latency_max(xi, xj, com, interpret=True)
        # one batched device→host transfer per shape, not one per operand
        got, want = jax.device_get((out, ref.edge_latency_ref(xi, xj, com)))
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_thousand_candidates_single_dispatch():
    """Acceptance: ≥1000 (scenario × placement) scores from ONE jitted call,
    spot-checked against the oracle."""
    rng = np.random.default_rng(7)
    n_ops, n_dev = 10, 16
    g = random_dag(n_ops, 0.4, rng)
    fleets = _random_fleets(rng, n_dev, 8)
    xs = [random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng, 0.5)
          for _ in range(128)]
    ev = BatchedEvaluator(g)
    grid = np.asarray(ev.score_grid(pack_placements(xs), pack_fleets(fleets)))
    assert grid.size == 8 * 128 >= 1000
    assert np.isfinite(grid).all() and (grid >= 0).all()
    for si, pi in [(0, 0), (3, 77), (7, 127)]:
        want = latency(g, fleets[si], xs[pi])
        assert grid[si, pi] == pytest.approx(want, rel=REL, abs=1e-6)


def test_compute_extension_rejected():
    rng = np.random.default_rng(0)
    g = random_dag(3, 0.5, rng)
    with pytest.raises(NotImplementedError):
        BatchedEvaluator(g, CostConfig(include_compute=True))


def test_mismatched_fleet_sizes_rejected():
    rng = np.random.default_rng(0)
    fleets = _random_fleets(rng, 4, 1) + _random_fleets(rng, 5, 1)
    with pytest.raises(ValueError):
        pack_fleets(fleets)


def test_latency_com_fn_scalar_twin():
    """The unbatched com-traced twin (what BatchedEvaluator vmaps) matches
    the oracle on a single (placement, fleet) pair, alpha on and off."""
    import jax
    import jax.numpy as jnp

    from repro.core import SmoothConfig
    from repro.core.jaxmodel import make_latency_com_fn

    rng = np.random.default_rng(11)
    g = random_dag(6, 0.5, rng)
    fleet = _random_fleets(rng, 5, 1)[0]
    x = random_placement(6, np.ones((6, 5), bool), rng, 0.3)
    # hoist the host→device conversions out of the alpha loop; pull each
    # scalar back with one explicit device_get per dispatch
    x32 = jnp.asarray(x, jnp.float32)
    com32 = jnp.asarray(fleet.com_matrix(), jnp.float32)
    for alpha in (0.0, 0.4):
        lat_fn = make_latency_com_fn(g, SmoothConfig(alpha=alpha))
        got = jax.device_get(lat_fn(x32, com32))
        want = latency(g, fleet, x, CostConfig(alpha=alpha))
        assert float(got) == pytest.approx(want, rel=REL, abs=1e-6)
