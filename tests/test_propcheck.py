"""The hypothesis-fallback shim itself: both decorator orderings honor
max_examples, and draws are deterministic per test name."""

from repro.testing.propcheck import given, settings, strategies as st


def test_settings_below_given_honored():
    calls = []

    @given(st.integers(0, 10))
    @settings(max_examples=7)
    def t(n):
        calls.append(n)

    t()
    assert len(calls) == 7


def test_settings_above_given_honored():
    calls = []

    @settings(max_examples=9)
    @given(st.integers(0, 10))
    def t(n):
        calls.append(n)

    t()
    assert len(calls) == 9


def test_draws_deterministic_per_name():
    seen = []

    def make():
        @given(st.integers(0, 10**6), x=st.sampled_from(["a", "b", "c"]))
        @settings(max_examples=5)
        def stable_name(n, x):
            seen.append((n, x))

        return stable_name

    make()()
    first = list(seen)
    seen.clear()
    make()()
    assert seen == first


def test_composite_draws():
    @st.composite
    def pair(draw):
        return (draw(st.integers(0, 5)), draw(st.booleans()))

    out = []

    @given(pair())
    @settings(max_examples=4)
    def t(p):
        out.append(p)

    t()
    assert len(out) == 4
    assert all(0 <= a <= 5 and isinstance(b, bool) for a, b in out)
