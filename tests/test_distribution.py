"""Distribution-layer tests on 8 fake host devices (subprocess: device count
locks at jax init, so these run in children with their own XLA_FLAGS)."""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
ENV = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/tmp",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "JAX_PLATFORMS": "cpu"}


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr + r.stdout
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    """A REAL sharded train step on a 2×4 mesh produces the same loss as the
    unsharded single-device run (GSPMD correctness end-to-end)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh, named_shardings, use_mesh
from repro.configs import get_smoke_config
from repro.models.api import build_model
from repro.launch.shardings import fsdp_specs
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.steps import make_train_step

cfg = get_smoke_config("granite_8b").replace(act_dtype="float32")
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
opt_cfg = AdamWConfig(lr=1e-3)
opt = adamw_init(params, opt_cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32), dtype=np.int32)),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32), dtype=np.int32))}
step = make_train_step(model, cfg, opt_cfg)

# single-device reference
_, _, m0 = jax.jit(step)(params, opt, batch)
loss0 = float(m0["loss"])

mesh = make_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    pspecs = fsdp_specs(model.param_specs(), jax.eval_shape(model.init_params, jax.random.PRNGKey(0)), mesh)
    j = jax.jit(step, in_shardings=named_shardings(mesh, (pspecs, None, P("data"))))
    sp = jax.device_put(params, jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P)))
    batch_sh = jax.device_put(batch, jax.sharding.NamedSharding(mesh, P("data")))
    p2, o2, m1 = j(sp, opt, batch_sh)
    loss1 = float(m1["loss"])
print("LOSSES", loss0, loss1)
assert abs(loss0 - loss1) < 1e-3, (loss0, loss1)
""")
    assert "LOSSES" in out


@pytest.mark.slow
def test_mesh_and_dryrun_cell_on_8_devices():
    """make_production_mesh shape contract + a miniature dry-run cell
    (reduced config, 2×4 mesh) lowers, compiles and reports collectives."""
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh, named_shardings, use_mesh
from repro.configs import get_smoke_config
from repro.models.api import build_model
from repro.launch.shardings import fsdp_specs, input_specs
from repro.perf.hlo import analyze_module
from repro.train.optim import AdamWConfig, adamw_init, opt_state_specs
from repro.train.steps import make_train_step
import dataclasses

cfg = get_smoke_config("qwen3_32b")
mesh = make_mesh((2,4), ("data","model"))
model = build_model(cfg)
with use_mesh(mesh):
    params_sds = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = fsdp_specs(model.param_specs(), params_sds, mesh)
    opt_cfg = AdamWConfig()
    opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
    ospecs = opt_state_specs(pspecs, opt_cfg)
    step = make_train_step(model, cfg, opt_cfg)
    def ws(t, s):
        return jax.tree.map(lambda a, sp: jax.ShapeDtypeStruct(a.shape, a.dtype,
            sharding=jax.sharding.NamedSharding(mesh, sp)), t, s,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    batch = {k: jax.ShapeDtypeStruct((8, 32), jnp.int32,
             sharding=jax.sharding.NamedSharding(mesh, P("data")))
             for k in ("tokens", "labels")}
    j = jax.jit(step, in_shardings=named_shardings(mesh, (pspecs, ospecs, P("data"))),
                out_shardings=named_shardings(mesh, (pspecs, ospecs, None)), donate_argnums=(0,1))
    comp = j.lower(ws(params_sds, pspecs), ws(opt_sds, ospecs), batch).compile()
    stats = analyze_module(comp.as_text())
    mem = comp.memory_analysis()
    print("FLOPS", stats.flops, "COLL", stats.collectives.total_count,
          "TEMP", mem.temp_size_in_bytes)
    assert stats.flops > 0
    assert stats.collectives.total_count > 0  # TP/DP collectives present
""")
    assert "FLOPS" in out


def test_production_mesh_shapes():
    """Mesh contract only (needs 256/512 devices → subprocess)."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh, mesh_chips, data_axes
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}
assert mesh_chips(m1) == 256
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
assert mesh_chips(m2) == 512
assert data_axes(m2) == ("pod", "data")
print("MESH OK")
""")
    assert "MESH OK" in out


def test_autoshard_prefers_tp_for_big_models():
    from repro.core.autoshard import choose_layout, estimate_layout, Layout
    best = choose_layout(
        chips=256, pods=1, n_layers=62, d_model=7168, d_ff=19200,
        vocab=32256, seq=4096, global_batch=256, n_params=33e9)
    assert best.layout.tp >= 2  # pure DP can't be optimal at 33B
    # multi-pod: DCI pricing pushes the estimate up
    single = estimate_layout(
        Layout(dp=16, tp=16), n_layers=62, d_model=7168, d_ff=19200,
        vocab=32256, seq=4096, global_batch=256, n_params=33e9)
    multi = estimate_layout(
        Layout(dp=32, tp=16, pods=2), n_layers=62, d_model=7168, d_ff=19200,
        vocab=32256, seq=4096, global_batch=512, n_params=33e9)
    assert multi.dci_collective_s > 0.0
    assert single.dci_collective_s == 0.0
