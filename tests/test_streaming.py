"""Streaming engine end-to-end: DAG execution under fractional placements,
selectivity accounting, straggler mitigation, elastic device loss."""

import numpy as np
import pytest

from repro.core import ExplicitFleet, uniform_placement
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import (StreamGraph, filter_op, map_op,
                                       quality_op, source, window_agg)

COM = np.array([[0.0, 1.0, 2.0],
                [1.0, 0.0, 1.5],
                [2.0, 1.5, 0.0]])


def _pipeline():
    ops = [
        source(),
        map_op("normalize", lambda r: (r - r.mean()) / (r.std() + 1e-9),
               work=1.0),
        filter_op("threshold", lambda r: r[:, 0] > -0.5, selectivity=0.7),
        window_agg("window_mean", window=4),
    ]
    edges = [(0, 1), (1, 2), (2, 3)]
    return StreamGraph(ops, edges)


def test_engine_runs_and_respects_selectivity():
    g = _pipeline()
    fleet = ExplicitFleet(com_cost=COM)
    x = uniform_placement(g.meta.n_ops, np.ones((g.meta.n_ops, 3), bool))
    eng = StreamingEngine(g, fleet, x)
    rng = np.random.default_rng(0)
    batch = rng.normal(size=(256, 4))
    rep = eng.run_batch(batch)
    assert rep.rows_in == 256
    out = rep.rows_out["window_mean"]
    # filter keeps ~70% (here: >−0.5 of standard normal ≈ 69%), window /4
    assert 20 < out < 64
    assert rep.modeled_latency > 0.0
    assert rep.edge_latencies.shape == (3,)


def test_quality_operator_drops_bad_rows():
    ops = [source(), quality_op(threshold=0.5)]
    g = StreamGraph(ops, [(0, 1)])
    fleet = ExplicitFleet(com_cost=COM)
    x = uniform_placement(2, np.ones((2, 3), bool))
    eng = StreamingEngine(g, fleet, x)
    rng = np.random.default_rng(1)
    batch = rng.integers(0, 50, (64, 32)).astype(float)
    batch[:16] = -1  # fully-missing rows → low completeness
    rep = eng.run_batch(batch)
    assert rep.rows_out["dq_check"] <= 48


def test_straggler_mitigation_reduces_modeled_latency():
    g = _pipeline()
    fleet = ExplicitFleet(com_cost=COM)
    x = uniform_placement(g.meta.n_ops, np.ones((g.meta.n_ops, 3), bool))
    eng = StreamingEngine(g, fleet, x)
    # device 2 becomes 10× slower: fold into fleet, re-optimize
    before = eng.run_batch(np.random.default_rng(2).normal(size=(64, 4)))
    res = eng.degrade_and_replace(device=2, factor=10.0)
    # mass on the degraded device shrinks vs uniform
    assert eng.x[:, 2].sum() <= x[:, 2].sum() + 1e-9
    # and the re-optimized placement beats keeping the old one on the
    # degraded fleet
    from repro.core import CostConfig, latency
    lat_old = latency(g.meta, eng.fleet, x,
                      CostConfig(include_compute=True))
    assert res.F <= lat_old + 1e-9


def test_elastic_device_loss():
    g = _pipeline()
    fleet = ExplicitFleet(com_cost=COM)
    n = g.meta.n_ops
    x = uniform_placement(n, np.ones((n, 3), bool))
    eng = StreamingEngine(g, fleet, x)
    eng.remove_device(1)
    assert eng.fleet.n_devices == 2
    assert eng.x.shape == (n, 2)
    np.testing.assert_allclose(eng.x.sum(axis=1), 1.0, atol=1e-6)
    rep = eng.run_batch(np.random.default_rng(3).normal(size=(64, 4)))
    assert rep.rows_out["window_mean"] > 0


def test_monitor_flags_stragglers():
    from repro.runtime.stragglers import StragglerMonitor
    mon = StragglerMonitor(n_devices=4, threshold=1.5)
    for _ in range(5):
        mon.observe(np.array([1.0, 1.1, 0.9, 4.0]))
    flagged = mon.stragglers()
    assert [u for u, _ in flagged] == [3]
    assert flagged[0][1] > 3.0


def test_rescale_plan():
    from repro.runtime.elastic import plan_rescale
    plan = plan_rescale(old_devices=256, surviving=240, model_ways=16,
                        global_batch=256)
    assert plan.new_devices == 240
    assert plan.data_ways == 15
    assert plan.global_batch == 256  # kept; accumulation handles remainder
    assert plan.new_devices % plan.model_ways == 0
    with pytest.raises(ValueError):
        plan_rescale(256, 10, 16, 256)


def test_quality_scores_jnp_matches_numpy():
    """The jnp twin computes the SAME score as the numpy reference —
    completeness, validity AND repetition, same weights (like the
    costmodel/jaxmodel pairing)."""
    from repro.streaming.quality import quality_scores, quality_scores_jnp

    rng = np.random.default_rng(0)
    for trial in range(8):
        B, S = int(rng.integers(2, 24)), int(rng.integers(4, 48))
        toks = rng.integers(-1, 30, (B, S))
        if trial == 2:
            toks[0] = 7          # stuck sensor → repetition term must bite
        if trial == 3:
            toks[1] = -1         # fully-missing row
        a = quality_scores(toks)
        b = np.asarray(quality_scores_jnp(toks))
        np.testing.assert_allclose(a, b, atol=1e-5)
    # the repetition term is actually wired in: a stuck row scores lower
    stuck = np.full((1, 16), 3)
    varied = np.arange(16).reshape(1, 16) % 7
    assert float(np.asarray(quality_scores_jnp(stuck))[0]) < \
        float(np.asarray(quality_scores_jnp(varied))[0])
