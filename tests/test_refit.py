"""refit_from_replay recovers synthetic ground truth: traces generated from
a fleet with KNOWN com-scale/speed perturbations re-fit to the known
parameters, and the refit belief explains the window better than the stale
one (property-tested via hypothesis or the repro.testing.propcheck shim)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing.propcheck import given, settings, strategies as st

from repro.core.calibration import (ReplayWindow, fit_work_unit,
                                    normalized_drift, refit_from_replay)
from repro.core.costmodel import latency
from repro.core.devices import ExplicitFleet
from repro.core.graph import Operator, OpGraph


def _chain_graph(n_ops: int, sel: float = 1.2, work: float = 0.5) -> OpGraph:
    ops = [Operator(f"op{i}", selectivity=sel, work=work)
           for i in range(n_ops)]
    return OpGraph(ops, [(i, i + 1) for i in range(n_ops - 1)])


def _base_fleet(rng: np.random.Generator, v: int) -> ExplicitFleet:
    com = rng.uniform(0.5, 2.0, (v, v))
    com = (com + com.T) / 2.0
    np.fill_diagonal(com, 0.0)
    return ExplicitFleet(com_cost=com)


def _window_from_truth(rng, graph, v, d_true, com_scale, base,
                       t_ticks: int = 10, work_unit: float = 1e-6):
    """Synthesize the observations the TRUE world (degrade d_true, com
    scaled by com_scale) would emit under the occupancy/latency models."""
    true_com = base.com_cost * np.outer(d_true, d_true) * com_scale
    np.fill_diagonal(true_com, 0.0)
    true_fleet = ExplicitFleet(com_cost=true_com, speed=1.0 / d_true)
    xs = np.stack([rng.dirichlet(np.ones(v), size=graph.n_ops)
                   for _ in range(t_ticks)])
    rates = rng.uniform(50.0, 300.0, t_ticks)
    cum = graph.cumulative_rates()
    wk = np.array([op.work * cum[i]
                   for i, op in enumerate(graph.operators)])
    busy = work_unit * np.einsum("i,tiu->tu", wk, xs) \
        * rates[:, None] * d_true[None, :]
    obs = np.array([latency(graph, true_fleet, x) for x in xs])
    return ReplayWindow(rates=rates, busy=busy, observed_latency=obs, xs=xs)


@settings(max_examples=15)
@given(seed=st.integers(0, 10_000),
       v=st.integers(4, 8),
       factor=st.floats(2.0, 20.0),
       com_scale=st.floats(1.3, 3.0))
def test_refit_recovers_ground_truth(seed, v, factor, com_scale):
    """Known single-straggler degrade + global com scale → both recovered
    within tolerance, and post-refit drift < pre-refit drift (≈ 0: the
    synthetic world IS the model family)."""
    rng = np.random.default_rng(seed)
    graph = _chain_graph(4)
    base = _base_fleet(rng, v)
    d_true = np.ones(v)
    d_true[int(rng.integers(v))] = factor
    window = _window_from_truth(rng, graph, v, d_true, com_scale, base)
    refit = refit_from_replay(graph, base, window)
    np.testing.assert_allclose(refit.degrade, d_true, rtol=0.1)
    assert refit.com_scale == pytest.approx(com_scale, rel=0.1)
    assert refit.pre_drift > refit.post_drift
    assert refit.post_drift < 0.05
    # the refit fleet reproduces the observed latencies
    relat = np.array([latency(graph, refit.fleet, x) for x in window.xs])
    np.testing.assert_allclose(relat, window.observed_latency, rtol=2e-2)


def test_refit_uniform_slowdown_needs_work_unit_anchor():
    """A fleet-wide uniform slowdown is invisible to the self-anchored
    (median) fit but recovered when the busy unit was calibrated on a
    healthy window first — the reason the controller stores work_unit."""
    rng = np.random.default_rng(3)
    graph = _chain_graph(4)
    v = 6
    base = _base_fleet(rng, v)
    healthy = _window_from_truth(rng, graph, v, np.ones(v), 1.0, base,
                                 work_unit=1e-6)
    wu = fit_work_unit(graph, base, healthy)
    assert wu == pytest.approx(1e-6, rel=0.05)
    d_true = np.full(v, 8.0)  # every device slows 8×
    drifted = _window_from_truth(rng, graph, v, d_true, 1.0, base,
                                 work_unit=1e-6)
    blind = refit_from_replay(graph, base, drifted)
    np.testing.assert_allclose(blind.degrade, 1.0, rtol=0.05)  # invisible
    anchored = refit_from_replay(graph, base, drifted, work_unit=wu)
    np.testing.assert_allclose(anchored.degrade, 8.0, rtol=0.1)


def test_refit_region_pooling_covers_blind_devices():
    """A device with no placement mass emits no busy signal; its degrade
    estimate must be inherited from its region-mates (outages take whole
    regions down — dumping mass on the blind device would be a trap)."""
    rng = np.random.default_rng(4)
    graph = _chain_graph(3)
    v = 6
    base = _base_fleet(rng, v)
    base = ExplicitFleet(com_cost=base.com_cost,
                         region=np.array([0, 0, 0, 1, 1, 1]))
    d_true = np.array([1.0, 1.0, 1.0, 16.0, 16.0, 16.0])
    window = _window_from_truth(rng, graph, v, d_true, 1.0, base)
    # blind device 5: zero mass in every placement ⇒ zero busy signal
    xs = window.xs.copy()
    xs[:, :, 5] = 0.0
    xs = xs / xs.sum(axis=2, keepdims=True)
    cum = graph.cumulative_rates()
    wk = np.array([op.work * cum[i]
                   for i, op in enumerate(graph.operators)])
    busy = 1e-6 * np.einsum("i,tiu->tu", wk, xs) \
        * window.rates[:, None] * d_true[None, :]
    obs = np.array([latency(graph, ExplicitFleet(
        com_cost=base.com_cost * np.outer(d_true, d_true)
        * (1 - np.eye(v))), x) for x in xs])
    window = ReplayWindow(rates=window.rates, busy=busy,
                          observed_latency=obs, xs=xs)
    refit = refit_from_replay(graph, base, window)
    assert refit.degrade[5] == pytest.approx(16.0, rel=0.15)


def test_refit_pooling_weights_by_observation_count():
    """Two observed devices in a degraded region: one with real load (true
    ratio 16×), one with a 1e-9 sliver of placement mass whose busy samples
    are quantization noise (ratio looks healthy).  The blind region-mate
    must inherit ≈16 from the WELL-observed device — an unweighted median
    would average the two estimates (→ ~8.5) and dilute the only real one."""
    rng = np.random.default_rng(7)
    graph = _chain_graph(3)
    v = 4
    base = _base_fleet(rng, v)
    base = ExplicitFleet(com_cost=base.com_cost,
                         region=np.array([0, 0, 0, 1]))
    d_true = np.array([16.0, 16.0, 16.0, 1.0])
    t = 8
    xs = np.zeros((t, graph.n_ops, v))
    xs[:, :, 0] = 0.5 - 1e-9   # well observed, degraded
    xs[:, :, 1] = 1e-9         # sliver of mass, same region
    xs[:, :, 3] = 0.5          # healthy anchor region
    rates = np.full(t, 200.0)
    cum = graph.cumulative_rates()
    wk = np.array([op.work * cum[i]
                   for i, op in enumerate(graph.operators)])
    busy = 1e-6 * np.einsum("i,tiu->tu", wk, xs) \
        * rates[:, None] * d_true[None, :]
    # the sliver device's busy is quantization noise — it reads HEALTHY
    # even though its region runs 16× slow
    busy[:, 1] /= 16.0
    window = ReplayWindow(rates=rates, busy=busy,
                          observed_latency=busy.max(axis=1), xs=xs)
    refit = refit_from_replay(graph, base, window)
    assert refit.degrade[0] == pytest.approx(16.0, rel=0.1)
    # blind device 2 pools the work-mass-weighted estimate, not the average
    assert refit.degrade[2] == pytest.approx(16.0, rel=0.15)
    # the evidence fields expose exactly what the pool used
    assert refit.signal is not None and refit.obs_weight is not None
    assert bool(refit.signal[1]) and not bool(refit.signal[2])
    assert refit.obs_weight[0] > 1e6 * refit.obs_weight[1]


def test_refit_selectivity_from_row_counters():
    """With per-op row counters the refit graph carries the observed
    selectivities, not the nominal ones."""
    graph = _chain_graph(3, sel=1.0, work=0.5)
    v, t = 4, 6
    rng = np.random.default_rng(5)
    base = _base_fleet(rng, v)
    xs = np.stack([rng.dirichlet(np.ones(v), size=3) for _ in range(t)])
    rates = np.full(t, 100.0)
    rows_in = np.stack([[100.0, 100.0, 50.0]] * t)   # op1 drifted to s=0.5
    rows_out = np.stack([[100.0, 50.0, 50.0]] * t)
    cumw = np.array([0.5, 0.5, 0.5])
    busy = 1e-6 * np.einsum("ti,tiu->tu", rows_in * cumw[None, :], xs)
    obs = np.array([latency(graph, base, x) for x in xs])
    window = ReplayWindow(rates=rates, busy=busy, observed_latency=obs,
                          xs=xs, op_rows_in=rows_in, op_rows_out=rows_out)
    refit = refit_from_replay(graph, base, window)
    assert refit.sel_scale[1] == pytest.approx(0.5, rel=1e-6)
    assert refit.graph.operators[1].selectivity == pytest.approx(0.5)
    assert refit.sel_scale[0] == pytest.approx(1.0)


def test_refit_rejects_tiny_windows():
    rng = np.random.default_rng(6)
    graph = _chain_graph(3)
    base = _base_fleet(rng, 4)
    w = _window_from_truth(rng, graph, 4, np.ones(4), 1.0, base, t_ticks=1)
    with pytest.raises(ValueError, match="≥2 ticks"):
        refit_from_replay(graph, base, w)


def test_normalized_drift_basics():
    obs = np.array([2.0, 2.0, 2.0])
    assert normalized_drift(obs, obs) == 0.0
    assert normalized_drift(obs, obs / 2.0) == pytest.approx(1.0)
    assert np.isnan(normalized_drift(np.array([1.0]), np.array([1.0])))


def test_window_from_plain_replay_report():
    """The no-controller path: replay a trace, lift the window straight off
    the ReplayReport (trailing constant-V suffix, max-busy latency proxy),
    and refit without error."""
    from repro.sim import ScenarioConfig, replay_trace, scenario_batch
    from repro.streaming.engine import StreamingEngine
    from repro.streaming.operators import (StreamGraph, filter_op, map_op,
                                           source)
    from repro.core.placement import uniform_placement

    rng = np.random.default_rng(9)
    ops = [source(),
           map_op("normalize", lambda r: r - r.mean()),
           filter_op("keep", lambda r: r[:, 0] > 0.0, selectivity=0.5)]
    sg = StreamGraph(ops, [(0, 1), (1, 2)])
    cfg = ScenarioConfig(trace_len=6, base_rate=24.0, loss_prob=0.0,
                         degrade_prob=0.0)
    s = scenario_batch(rng, 1, cfg, graph=sg.meta)[0]
    x = uniform_placement(sg.meta.n_ops,
                          np.ones((sg.meta.n_ops, s.n_devices), bool))
    eng = StreamingEngine(sg, s.fleet, x, observed="work")
    report = replay_trace(eng, s.trace, rng)
    window = ReplayWindow.from_report(report, x)
    assert window.n_ticks == 6
    assert window.busy.shape == (6, s.n_devices)
    refit = refit_from_replay(sg.meta, s.fleet, window)
    assert np.isfinite(refit.com_scale) and refit.com_scale > 0.0
    assert refit.degrade.shape == (s.n_devices,)
