"""Property tests: the unified multi-objective layer (repro.core.objectives)
— every batched/structured objective twin against its float64 numpy oracle
(≤1e-5 relative) on random graphs/fleets, including degrade ≠ 1, alpha > 0,
and the S == 1 broadcast case — plus the ObjectiveSet scalarization contract
through PlacementProblem / robust search and the score_grid dq validation."""

import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.core import (
    OBJECTIVES,
    CostConfig,
    ExplicitFleet,
    ObjectiveSet,
    PlacementProblem,
    RegionFleet,
    device_occupancy,
    greedy_transfer,
    latency,
    network_movement,
    objective_F,
    random_dag,
    random_placement,
)
from repro.sim import (
    BatchedEvaluator,
    ScenarioConfig,
    pack_fleets,
    pack_placements,
    pack_region_fleets,
    pack_speeds,
    region_scenario_batch,
    robust_placement,
    scenario_robust_search,
)

SETTINGS = dict(max_examples=15, deadline=None)
REL = 1e-5
ALL_OBJECTIVES = tuple(sorted(OBJECTIVES))


def _payload_dag(rng, n_ops):
    """Random DAG whose operators carry out_bytes/work so no objective is
    degenerate."""
    g = random_dag(n_ops, edge_prob=0.5, rng=rng)
    g = type(g)(
        [dataclasses.replace(op,
                             out_bytes=float(rng.uniform(0.25, 4.0)),
                             work=float(rng.uniform(0.05, 0.5)))
         for op in g.operators],
        list(g.edges))
    return g


def _region_fleets(rng, n_dev, n_fleets):
    """RegionFleets sharing one layout: random inter matrices, lognormal
    speeds, and degrade ≠ 1 on all but the first."""
    n_regions = int(rng.integers(1, n_dev + 1))
    region = rng.integers(0, n_regions, n_dev)
    fleets = []
    for k in range(n_fleets):
        inter = rng.uniform(0.1, 2.0, (n_regions, n_regions))
        inter = (inter + inter.T) / 2
        degrade = None if k == 0 else rng.uniform(1.0, 4.0, n_dev)
        fleets.append(RegionFleet(region=region, inter=inter,
                                  degrade=degrade,
                                  speed=rng.lognormal(0.0, 0.3, n_dev)))
    return fleets


@st.composite
def instances(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    alpha = draw(st.sampled_from([0.0, 0.5]))
    rng = np.random.default_rng(seed)
    n_ops = int(rng.integers(2, 7))
    n_dev = int(rng.integers(2, 8))
    g = _payload_dag(rng, n_ops)
    fleets = _region_fleets(rng, n_dev, int(rng.integers(1, 4)))
    xs = [random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng,
                           sparsity=float(rng.uniform(0.0, 0.6)))
          for _ in range(int(rng.integers(1, 4)))]
    return g, fleets, xs, CostConfig(alpha=alpha)


@given(instances())
@settings(**SETTINGS)
def test_every_twin_matches_oracle(inst):
    """One multi-objective score_grid dispatch on BOTH representations ==
    every objective's scalar oracle, including the weighted scalarization
    (covers degrade ≠ 1 fleets, alpha > 0, per-scenario dq, S == 1)."""
    g, fleets, xs, cfg = inst
    obj = ObjectiveSet.of(*ALL_OBJECTIVES,
                          weights=[0.5 + 0.25 * k
                                   for k in range(len(ALL_OBJECTIVES))])
    ev = BatchedEvaluator(g, cfg)
    P = pack_placements(xs)
    beta = 0.8
    dq = np.linspace(0.1, 0.9, len(fleets))
    packs = [pack_region_fleets(fleets),
             pack_fleets(fleets)]
    speeds = [None, pack_speeds(fleets)]
    for pack, speed in zip(packs, speeds):
        res = ev.score_grid(P, pack, dq=dq, beta=beta, objectives=obj,
                            speed=speed)
        assert res.names == obj.names
        # one batched device→host transfer per score_grid result, not one
        # sync per objective/grid access inside the comparison loops
        grids, scal = jax.device_get(({n: res[n] for n in obj.names},
                                      res.scalarized))
        assert scal.shape == (len(fleets), len(xs))
        for name in obj.names:
            grid = grids[name]
            for si, fleet in enumerate(fleets):
                for pi, x in enumerate(xs):
                    want = OBJECTIVES[name].scalar(g, fleet, x,
                                                   float(dq[si]), beta, cfg)
                    assert grid[si, pi] == pytest.approx(
                        want, rel=REL, abs=1e-6), (name, si, pi)
        # weighted scalarization == Σ w_k · grid_k == scalar_total oracle
        stack = np.stack([grids[n] for n in obj.names])
        np.testing.assert_allclose(
            scal,
            np.einsum("k,ksp->sp", obj.weights, stack), rtol=1e-6, atol=1e-6)
        want = obj.scalar_total(g, fleets[0], xs[0], float(dq[0]), beta, cfg)
        assert scal[0, 0] == pytest.approx(want, rel=REL, abs=1e-6)


@given(instances())
@settings(**SETTINGS)
def test_single_scenario_broadcast(inst):
    """An S == 1 family/pack broadcasts its multi-objective grids across the
    whole placement batch on both representations."""
    g, fleets, xs, cfg = inst
    obj = ObjectiveSet.of(*ALL_OBJECTIVES)
    ev = BatchedEvaluator(g, cfg)
    P = pack_placements(xs)
    for pack, speed in ((pack_region_fleets(fleets[:1]), None),
                        (pack_fleets(fleets[:1]), pack_speeds(fleets[:1]))):
        res = ev.score_grid(P, pack, dq=0.4, beta=0.6, objectives=obj,
                            speed=speed)
        grids = jax.device_get({n: res[n] for n in obj.names})
        for name in obj.names:
            grid = grids[name]
            assert grid.shape == (1, len(xs))
            for pi, x in enumerate(xs):
                want = OBJECTIVES[name].scalar(g, fleets[0], x, 0.4, 0.6, cfg)
                assert grid[0, pi] == pytest.approx(want, rel=REL, abs=1e-6)


@given(instances())
@settings(**SETTINGS)
def test_scalar_movement_matches_bruteforce(inst):
    """The factorized scalar network_movement (segment-sum on RegionFleets,
    no materialized com, no per-edge outer) == the brute-force bilinear."""
    g, fleets, xs, _ = inst
    rates = g.cumulative_rates()
    for fleet in fleets:
        com = fleet.com_matrix()
        ef = ExplicitFleet(com_cost=com)
        for weighted in (False, True):
            brute = 0.0
            for i, j in g.edges:
                op = g.operators[i]
                outer = np.outer(xs[0][i], xs[0][j])
                np.fill_diagonal(outer, 0.0)
                if weighted:
                    outer = outer * com
                brute += rates[i] * op.selectivity * op.out_bytes * outer.sum()
            for fl in (fleet, ef):
                assert network_movement(g, fl, xs[0], weighted) \
                    == pytest.approx(brute, rel=1e-9, abs=1e-12)


def test_latency_f_spec_builders_match_oracle():
    """The latency_f spec's own dense/structured builders match the oracle.
    (score_grid routes latency through the evaluator's Pallas-aware
    machinery instead, but the spec twins are the public reference — this
    pins them so the two routes can't drift.)"""
    import jax.numpy as jnp

    rng = np.random.default_rng(8)
    g = _payload_dag(rng, 5)
    fleet = _region_fleets(rng, 6, 2)[1]
    x = random_placement(5, np.ones((5, 6), bool), rng, 0.3)
    cfg = CostConfig(alpha=0.5)
    spec = OBJECTIVES["latency_f"]
    dq, beta = 0.25, 0.6
    want = spec.scalar(g, fleet, x, dq, beta, cfg)
    ones = jnp.ones(6)
    raw = spec.build_dense(g, cfg)(
        jnp.asarray(x), jnp.asarray(fleet.com_matrix()), ones)
    assert float(spec.finish(raw, dq, beta)) == pytest.approx(
        want, rel=REL, abs=1e-6)
    raw = spec.build_structured(g, fleet.region, fleet.n_regions,
                                fleet.self_cost, cfg)(
        jnp.asarray(x), jnp.asarray(fleet.inter),
        jnp.asarray(fleet.degrade_or_ones()), ones)
    assert float(spec.finish(raw, dq, beta)) == pytest.approx(
        want, rel=REL, abs=1e-6)


def test_perturbed_fleet_keeps_effective_speed():
    """Materializing a degraded RegionFleet into a what-if ExplicitFleet
    must carry the compute slowdown along with the degraded links."""
    from repro.sim import perturbed_fleet

    rng = np.random.default_rng(9)
    g = _payload_dag(rng, 4)
    rf = _region_fleets(rng, 5, 1)[0].degrade_device(1, 4.0)
    ef = perturbed_fleet(rf, rng, jitter=0.0)
    x = np.full((4, 5), 0.2)
    np.testing.assert_allclose(device_occupancy(g, ef, x),
                               device_occupancy(g, rf, x), rtol=1e-12)


def test_occupancy_prices_degrade():
    """The §3.1 occupancy bugfix: a straggler with a degrade multiplier
    occupies proportionally longer (effective speed = speed / degrade), and
    degrade_device no longer double-counts by also dividing nominal speed."""
    rng = np.random.default_rng(5)
    g = _payload_dag(rng, 4)
    fleet = RegionFleet(region=np.zeros(3, dtype=np.int64),
                        inter=np.ones((1, 1)))
    base = device_occupancy(g, fleet, np.full((4, 3), 1 / 3))
    slow = fleet.degrade_device(1, 2.0)
    occ = device_occupancy(g, slow, np.full((4, 3), 1 / 3))
    np.testing.assert_allclose(occ[1], 2.0 * base[1], rtol=1e-12)
    np.testing.assert_allclose(occ[[0, 2]], base[[0, 2]], rtol=1e-12)
    # nominal speed untouched — the multiplier lives in degrade alone
    np.testing.assert_allclose(slow.speed, fleet.speed)


def test_score_grid_rejects_wronglength_dq():
    """dq must be a scalar or exactly (S,): a broadcastable-but-wrong (1,)
    (or a (P,) slipped in) raises with shapes in the message."""
    rng = np.random.default_rng(2)
    g = _payload_dag(rng, 3)
    fleets = _region_fleets(rng, 4, 3)
    xs = [random_placement(3, np.ones((3, 4), bool), rng) for _ in range(5)]
    ev = BatchedEvaluator(g)
    for pack in (pack_region_fleets(fleets), pack_fleets(fleets)):
        for bad in (np.array([0.1]), np.zeros(5), np.zeros((3, 1))):
            with pytest.raises(ValueError, match="scalar or shape"):
                ev.score_grid(pack_placements(xs), pack, dq=bad)
        # scalar and exact (S,) still fine
        ev.score_grid(pack_placements(xs), pack, dq=0.2)
        ev.score_grid(pack_placements(xs), pack, dq=np.full(3, 0.2))


def test_placement_problem_scores_weighted_sum():
    """PlacementProblem.score with an ObjectiveSet == the hand-built
    weighted sum of scalar oracles, and greedy_transfer descends it."""
    rng = np.random.default_rng(3)
    g = _payload_dag(rng, 5)
    fleet = _region_fleets(rng, 5, 2)[1]
    obj = ObjectiveSet.from_weights(latency_f=1.0, network_movement=0.05,
                                    occupancy_max=0.5)
    prob = PlacementProblem(g, fleet, beta=0.5, objectives=obj)
    x = random_placement(5, np.ones((5, 5), bool), rng)
    want = (1.0 * objective_F(latency(g, fleet, x), 0.2, 0.5)
            + 0.05 * network_movement(g, fleet, x)
            + 0.5 * device_occupancy(g, fleet, x).max())
    assert prob.score(x, dq=0.2) == pytest.approx(want, rel=1e-12)
    res = greedy_transfer(prob, max_rounds=5)
    assert res.F <= prob.score(x := res.x, res.dq_fraction) + 1e-9
    assert res.F == pytest.approx(prob.score(res.x, res.dq_fraction),
                                  rel=1e-12)


def test_robust_search_multi_objective_end_to_end():
    """scenario_robust_search with objectives: the structured one-dispatch
    scalarized grid drives min–max selection, and the reported F is the
    worst scenario's exact scalarized score."""
    rng = np.random.default_rng(11)
    cfg = ScenarioConfig(trace_len=4, n_regions=(3, 3),
                         devices_per_region=(2, 3))
    scens = region_scenario_batch(rng, 4, cfg)
    g = scens[0].graph
    obj = ObjectiveSet.from_weights(latency_f=1.0, network_movement_cost=0.1,
                                    occupancy_imbalance=0.25)
    x, worst, grid = robust_placement(g, scens, rng, n_candidates=24,
                                      objectives=obj)
    assert grid.shape == (4, 24)
    k = int(grid.max(axis=0).argmin())
    for si, s in enumerate(scens):
        want = obj.scalar_total(g, s.fleet, x)
        assert grid[si, k] == pytest.approx(want, rel=2e-5, abs=1e-6)
    res = scenario_robust_search(g, scens, rng, n_candidates=32,
                                 objectives=obj)
    fs = [obj.scalar_total(g, s.fleet, res.x) for s in scens]
    assert res.F == pytest.approx(max(fs), rel=1e-12)
    assert res.latency == pytest.approx(
        latency(g, scens[int(np.argmax(fs))].fleet, res.x), rel=1e-12)


def test_objective_set_validation():
    with pytest.raises(ValueError, match="unknown objective"):
        ObjectiveSet.of("latency")
    with pytest.raises(ValueError, match="weights"):
        ObjectiveSet.of("latency_f", weights=[1.0, 2.0])
    with pytest.raises(ValueError, match="duplicate"):
        ObjectiveSet.of("latency_f", "latency_f")
    with pytest.raises(ValueError, match="at least one"):
        ObjectiveSet.of()
    # speed is meaningless without objectives / on structured families
    rng = np.random.default_rng(4)
    g = _payload_dag(rng, 3)
    fleets = _region_fleets(rng, 4, 2)
    ev = BatchedEvaluator(g)
    xs = pack_placements([random_placement(3, np.ones((3, 4), bool), rng)])
    with pytest.raises(ValueError, match="objectives"):
        ev.score_grid(xs, pack_fleets(fleets), speed=pack_speeds(fleets))
    with pytest.raises(ValueError, match="speeds"):
        ev.score_grid(xs, pack_region_fleets(fleets), speed=np.ones(4),
                      objectives=ObjectiveSet.of("occupancy_max"))


def test_generated_graphs_carry_payloads():
    """sim graphs draw out_bytes/work, so movement and occupancy grids are
    non-degenerate on every generated family."""
    from repro.sim import random_graph

    rng = np.random.default_rng(6)
    for family in ("chain", "diamond", "fan_out", "fan_in", "layered"):
        g = random_graph(rng, family=family)
        assert all(op.work > 0.0 for op in g.operators)
        assert all(op.out_bytes > 0.0 for op in g.operators)
