"""End-to-end behaviour tests for the paper's system: the complete loop of
cost-model-driven placement → streaming execution → quality/latency
trade-off, plus a short real training run with DQ masking."""

import numpy as np
import pytest

from repro.core import (
    CostConfig,
    DQCoupling,
    ExplicitFleet,
    PlacementProblem,
    greedy_transfer,
    latency,
    objective_F,
    uniform_placement,
)
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import (StreamGraph, filter_op, map_op,
                                       quality_op, source, window_agg)


def _geo_fleet():
    """2 'regions' × 2 devices with WAN-like inter-region links."""
    com = np.array([
        [0.0, 0.2, 2.0, 2.2],
        [0.2, 0.0, 1.8, 2.0],
        [2.0, 1.8, 0.0, 0.3],
        [2.2, 2.0, 0.3, 0.0],
    ])
    return ExplicitFleet(com_cost=com, speed=np.array([1.0, 1.0, 2.0, 2.0]))


def test_optimized_placement_beats_uniform_on_geo_fleet():
    ops = [source(), map_op("clean", lambda r: r),
           filter_op("sel", lambda r: r[:, 0] > 0, 0.5),
           window_agg("agg", 4)]
    g = StreamGraph(ops, [(0, 1), (1, 2), (2, 3)])
    fleet = _geo_fleet()
    dq = DQCoupling(cap0=np.full(4, 1.5), load=np.zeros(4))
    prob = PlacementProblem(g.meta, fleet, CostConfig(alpha=0.01), beta=0.0,
                            dq=dq)
    uni = uniform_placement(g.meta.n_ops, prob.availability())
    res = greedy_transfer(prob)
    assert res.latency < latency(g.meta, fleet, uni, prob.cost_cfg)
    # and the engine actually runs under the optimized placement
    eng = StreamingEngine(g, fleet, res.x, alpha=0.01)
    rep = eng.run_batch(np.random.default_rng(0).normal(size=(128, 4)))
    assert rep.modeled_latency == pytest.approx(res.latency, rel=1e-9)


def test_dq_tradeoff_matches_paper_semantics():
    """Raising β makes a higher-DQ deployment win — the paper's §3 flip,
    solved by the optimizer instead of by hand."""
    ops = [source(), quality_op("dq", work=3.0), window_agg("agg", 2)]
    g = StreamGraph(ops, [(0, 1), (1, 2)])
    fleet = _geo_fleet()
    # DQ checks eat capacity on the near devices: higher dq forces mass out
    dq = DQCoupling(cap0=np.array([1.1, 1.1, 1.5, 1.5]),
                    load=np.array([0.5, 0.5, 0.0, 0.0]))
    dq_choice = {}
    for beta in (0.2, 5.0):
        prob = PlacementProblem(g.meta, fleet, beta=beta, dq=dq)
        res = greedy_transfer(prob)
        dq_choice[beta] = res.dq_fraction
    assert dq_choice[5.0] >= dq_choice[0.2]


def test_training_with_dq_masking_learns():
    """A tiny LM trained on the quality-masked stream reduces loss (full
    data path: corruption → scoring → loss mask → step)."""
    from repro.configs import get_smoke_config
    from repro.launch.train import run_training

    cfg = get_smoke_config("olmo_1b").replace(vocab=64)
    out = run_training(cfg, steps=80, global_batch=8, seq_len=32,
                       dq_fraction=0.5, lr=5e-3, log_every=10)
    losses = [l for _, l in out["losses"]]
    # hashed tokens are uniform-random: the floor is ln(64)=4.16; from a
    # ~4.6 init the model must at least learn the unigram distribution
    assert min(losses[-3:]) < losses[0] - 0.05, losses


def test_serve_wave_generates():
    from repro.configs import get_smoke_config
    from repro.launch.serve import serve_wave
    from repro.models.api import build_model
    import jax

    cfg = get_smoke_config("granite_8b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 16),
                                                dtype=np.int32)
    out, stats = serve_wave(model, cfg, params, prompts, gen_tokens=8)
    assert out.shape == (4, 8)
    assert (out >= 0).all() and (out < cfg.vocab).all()
    s = stats.summary()
    assert s["tokens_out"] == 32 and s["decode_s"] > 0
