"""Calibration layer: HLO → cost-model inputs, and the LM stage graph."""

import numpy as np
import pytest

from repro.core.calibration import calibrate_from_hlo, stage_graph_for_lm
from repro.core.costmodel import latency
from repro.core.devices import fleet_from_tpu_mesh
from repro.core.placement import uniform_placement


HLO = """
HloModule train, is_scheduled=true

ENTRY %main (x: bf16[1024,1024]) -> bf16[1024,1024] {
  %x = bf16[1024,1024]{1,0} parameter(0)
  ROOT %ar = bf16[1024,1024]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
}
"""


def test_calibrate_from_hlo():
    cal = calibrate_from_hlo(HLO, flops_per_device=1e12, n_pods=1,
                             chips_per_pod=256)
    # 2·B·(n−1)/n ring wire for a 2 MiB bf16 all-reduce over 16
    expect = 2 * 1024 * 1024 * 2 * 15 / 16
    assert cal.bytes_per_step == pytest.approx(expect)
    assert cal.step_comm_seconds() == pytest.approx(expect / 50e9)
    assert cal.fleet.n_devices == 256


def test_fleet_from_tpu_mesh_link_classes():
    fleet = fleet_from_tpu_mesh(n_pods=2, chips_per_pod=4, ici_gbps=50,
                                dci_gbps=5, unit_bytes=1e9)
    com = fleet.com_matrix()
    # intra-pod pair
    assert com[0, 1] == pytest.approx(1 / 50)
    # inter-pod pair is 10× more expensive
    assert com[0, 5] == pytest.approx(1 / 5)
    assert com[0, 0] == 0.0


def test_stage_graph_latency_orders_geo_vs_local():
    """The train-step stage graph priced on a geo fleet: splitting a stage
    across pods costs more than keeping it pod-local — the basic invariant
    the placement optimizer relies on."""
    g = stage_graph_for_lm(n_layers=4, d_model=256, d_ff=1024, vocab=1000,
                           seq=128, batch=8)
    fleet = fleet_from_tpu_mesh(n_pods=2, chips_per_pod=4)
    n = g.n_ops
    local = np.zeros((n, 8))
    local[:, :4] = 0.25  # everything in pod 0
    spread = np.full((n, 8), 1 / 8)  # fractions cross the DCI
    assert latency(g, fleet, local) < latency(g, fleet, spread)


def test_stage_graph_structure():
    g = stage_graph_for_lm(n_layers=3, d_model=64, d_ff=256, vocab=500,
                           seq=32, batch=4, moe_experts=8, top_k=2)
    assert g.n_ops == 7  # source, embed, 3 blocks, head, loss
    # source→embed→blocks→head→loss is a chain
    assert len(g.edge_paths()) == 1
    # MoE blocks carry the top-k duplication as selectivity
    assert g.operators[2].selectivity == 2.0