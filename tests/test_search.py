"""Decision-layer tests for the repro.search subsystem: Pareto extraction,
automatic objective normalization, joint (placement × dq) co-optimization,
and the incumbent-including DQ grid."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.core import (DQCoupling, ExplicitFleet, ObjectiveSet,
                        PlacementProblem, linear_graph)
from repro.core.optimizers import _dq_grid
from repro.core.placement import random_placement
from repro.search import (ObjectiveScales, candidate_values, dq_grid,
                          joint_dq_scores, pareto_front, pareto_mask,
                          robust_select, scalarize, scenario_robust_search)
from repro.sim import (BatchedEvaluator, ScenarioConfig, pack_placements,
                       region_scenario_batch)

SETTINGS = dict(max_examples=30, deadline=None)

OBJ3 = ObjectiveSet.from_weights(latency_f=1.0, network_movement=0.01,
                                 occupancy_max=0.1)


def _dominates(a, b):
    return bool((a <= b).all() and (a < b).any())


@st.composite
def value_matrices(draw, max_p=40, k=3):
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    p = draw(st.integers(2, max_p))
    return rng.uniform(0.0, 10.0, (p, k)), rng


# -- Pareto extraction --------------------------------------------------------

@given(value_matrices())
@settings(**SETTINGS)
def test_pareto_front_is_mutually_non_dominated(inst):
    values, _ = inst
    front = pareto_front(values)
    assert len(front) >= 1
    for a in range(len(front)):
        for b in range(len(front)):
            if a != b:
                assert not _dominates(front.values[a], front.values[b])


@given(value_matrices())
@settings(**SETTINGS)
def test_pareto_front_contains_weighted_argmin(inst):
    """For every strictly positive weight vector, the scalarization argmin
    is a non-dominated point, so its value vector must be on the front."""
    values, rng = inst
    front = pareto_front(values)
    for _ in range(8):
        w = rng.uniform(0.05, 2.0, values.shape[1])
        k = int(np.argmin(scalarize(values, w)))
        assert any(np.allclose(values[k], fv) for fv in front.values), \
            f"argmin {values[k]} for weights {w} missing from front"


def test_pareto_mask_keeps_duplicates_and_drops_dominated():
    values = np.array([[1.0, 2.0],
                       [1.0, 2.0],    # duplicate of a front point — kept
                       [2.0, 1.0],
                       [2.0, 2.0],    # dominated by both
                       [1.0, 3.0]])   # dominated by [1, 2]
    assert pareto_mask(values).tolist() == [True, True, True, False, False]


# -- automatic objective normalization ----------------------------------------

@given(value_matrices())
@settings(**SETTINGS)
def test_normalized_equal_weight_search_is_scale_invariant(inst):
    """Rescaling any one objective's units (v ↦ c·v) must not change the
    equal-weight argmin when scales are re-fit from the rescaled sample."""
    values, rng = inst
    k = values.shape[1]
    w = np.ones(k)
    base = int(np.argmin(scalarize(values, w, ObjectiveScales.fit(values))))
    for col in range(k):
        c = float(rng.uniform(0.01, 100.0))
        scaled = values.copy()
        scaled[:, col] *= c
        got = int(np.argmin(
            scalarize(scaled, w, ObjectiveScales.fit(scaled))))
        assert got == base, f"rescaling objective {col} by {c} moved argmin"


def test_normalization_handles_constant_objective():
    values = np.array([[1.0, 5.0], [2.0, 5.0], [3.0, 5.0]])
    scales = ObjectiveScales.fit(values)
    normed = scales.apply(values)
    assert np.allclose(normed[:, 1], 0.0)      # constant column → 0 exactly
    assert np.allclose(normed[:, 0], [0.0, 0.5, 1.0])


def test_scales_fit_ignores_infeasible_cells():
    values = np.array([[1.0, 2.0], [np.inf, 3.0], [3.0, 4.0]])
    scales = ObjectiveScales.fit(values)
    assert np.isfinite(scales.offset).all() and np.isfinite(scales.scale).all()
    assert scales.offset[0] == 1.0 and scales.scale[0] == 2.0


# -- Pareto over a real score_grid dispatch (≥3 objectives) -------------------

def test_pareto_from_single_score_grid_dispatch():
    rng = np.random.default_rng(3)
    cfg = ScenarioConfig(n_regions=(3, 3), devices_per_region=(2, 3),
                         n_ops=(5, 5), out_bytes=(0.5, 2.0),
                         op_work=(0.1, 0.5))
    scens = region_scenario_batch(rng, 4, cfg)
    g = scens[0].graph
    v = scens[0].n_devices
    xs = [random_placement(g.n_ops, np.ones((g.n_ops, v), bool), rng, 0.5)
          for _ in range(64)]
    ev = BatchedEvaluator(g)
    grids = ev.score_grid(pack_placements(xs),
                          np.stack([s.fleet.com_matrix() for s in scens]),
                          dq=0.3, beta=0.5, objectives=OBJ3)
    front = pareto_front(grids, scenario="worst")
    assert front.names == tuple(OBJ3.names) and len(front.names) == 3
    assert 1 <= len(front) <= 64
    # mutual non-domination over the worst-case envelope
    for a in range(len(front)):
        for b in range(len(front)):
            if a != b:
                assert not _dominates(front.values[a], front.values[b])
    # every weighted argmin over the same envelope sits on the front
    vals = candidate_values(grids, scenario="worst")
    for w in ([1.0, 0.01, 0.1], [0.1, 1.0, 1.0], [2.0, 0.5, 0.01]):
        k = int(np.argmin(scalarize(vals, w)))
        assert any(np.allclose(vals[k], fv) for fv in front.values)


# -- joint dq decision --------------------------------------------------------

def test_joint_dq_scores_picks_best_feasible_knob():
    lat = np.array([[2.0, 4.0], [3.0, 6.0]])          # (S=2, P=2)
    dqs = np.array([0.0, 0.5, 1.0])
    beta = 1.0
    feasible = np.array([[True, True, False],          # cand 0: dq ≤ 0.5
                         [True, True, True]])          # cand 1: any dq
    scores, idx = joint_dq_scores(lat, dqs, beta, feasible=feasible)
    assert np.allclose(scores[:, 0], lat[:, 0] / 1.5)  # best feasible: 0.5
    assert np.allclose(scores[:, 1], lat[:, 1] / 2.0)  # dq = 1
    assert idx[:, 0].tolist() == [1, 1] and idx[:, 1].tolist() == [2, 2]
    k, worst = robust_select(scores)
    assert k == 0 and worst[0] == pytest.approx(3.0 / 1.5)


def test_joint_dq_beats_placement_only_search():
    """Acceptance: on a DQCoupling-enabled fixture, co-optimizing dq with
    the placement finds a strictly better scalarized objective than the
    same search with the quality knob pinned."""
    rng = np.random.default_rng(7)
    cfg = ScenarioConfig(n_regions=(3, 3), devices_per_region=(2, 2),
                         n_ops=(4, 4))
    scens = region_scenario_batch(rng, 3, cfg)
    g = scens[0].graph
    coupling = DQCoupling(cap0=np.full(scens[0].n_devices, 1.5),
                          load=np.full(scens[0].n_devices, 0.4))
    fixed = scenario_robust_search(g, scens, np.random.default_rng(1),
                                   n_candidates=64, beta=1.5, dq=0.0,
                                   warm_start=False)
    joint = scenario_robust_search(g, scens, np.random.default_rng(1),
                                   n_candidates=64, beta=1.5,
                                   warm_start=False, co_optimize_dq=True,
                                   dq_coupling=coupling)
    assert joint.F < fixed.F
    assert joint.dq_fraction > 0.0
    # the chosen knob must respect the coupling's caps
    caps = coupling.caps(joint.dq_fraction)
    assert (joint.x.sum(axis=0) <= caps + 1e-7).all()


def test_joint_dq_reaches_core_shim():
    """The sim.replay delegator forwards the joint-dq kwargs."""
    from repro.sim import scenario_robust_search as sim_srs

    rng = np.random.default_rng(11)
    cfg = ScenarioConfig(n_regions=(2, 2), devices_per_region=(2, 2),
                         n_ops=(3, 3))
    scens = region_scenario_batch(rng, 2, cfg)
    g = scens[0].graph
    res = sim_srs(g, scens, rng, n_candidates=16, beta=1.0,
                  warm_start=False, co_optimize_dq=True)
    assert res.dq_fraction == pytest.approx(1.0)  # no coupling ⇒ dq pins to 1


# -- the incumbent-including DQ grid ------------------------------------------

def test_dq_grid_always_contains_incumbent():
    grid = dq_grid(beta=1.0, steps=5, include=(0.37,))
    assert 0.37 in grid.tolist()
    assert 0.0 in grid.tolist() and 1.0 in grid.tolist()
    assert np.all(np.diff(grid) > 0)                       # sorted, deduped
    # β = 0 keeps the degenerate {0} grid but still honors the incumbent
    assert dq_grid(beta=0.0, include=(0.5,)).tolist() == [0.0, 0.5]
    # out-of-range incumbents are clipped, not propagated
    assert dq_grid(beta=1.0, include=(1.7,)).max() == 1.0


def test_core_dq_grid_shim_matches():
    g = linear_graph([1.0, 1.0])
    fleet = ExplicitFleet(com_cost=np.array([[0.0, 1.0], [1.0, 0.0]]))
    prob = PlacementProblem(g, fleet, beta=2.0)
    assert 0.13 in _dq_grid(prob, include=(0.13,))
    prob0 = PlacementProblem(g, fleet, beta=0.0)
    assert _dq_grid(prob0) == [0.0]


def test_greedy_restart_keeps_incumbent_dq():
    """Re-optimizing from a previous result can no longer regress the dq
    term to a worse grid value: the incumbent is always a candidate."""
    from repro.search import greedy_transfer

    g = linear_graph([1.0, 1.5, 1.0])
    com = np.array([[0.0, 1.5, 2.0], [1.5, 0.0, 1.0], [2.0, 1.0, 0.0]])
    fleet = ExplicitFleet(com_cost=com)
    coupling = DQCoupling(cap0=np.full(3, 1.2), load=np.full(3, 0.2))
    prob = PlacementProblem(g, fleet, beta=1.0, dq=coupling)
    first = greedy_transfer(prob)
    incumbent_dq = 0.73  # an off-grid knob (e.g. chosen by a finer search)
    restart = greedy_transfer(prob, x0=first.x, dq0=incumbent_dq)
    base = prob.score(first.x, incumbent_dq)
    assert restart.F <= base + 1e-9


def test_scales_fit_degenerate_grid_is_guarded():
    """A degenerate grid (max == min for an objective) never yields a zero
    range: the scale is 1, every normalized value of that objective is
    EXACTLY 0 (so it contributes nothing to a normalized scalarization),
    +inf feasibility masks pass through, and nothing warns."""
    import warnings

    values = np.array([[1.0, 7.0, np.inf],
                       [2.0, 7.0, np.inf],
                       [3.0, 7.0, np.inf]])
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # all-NaN-slice etc. would fail here
        scales = ObjectiveScales.fit(values)
    assert (scales.scale > 0.0).all()
    normed = scales.apply(values)
    assert np.all(normed[:, 1] == 0.0)          # degenerate → exactly 0
    assert np.all(np.isinf(normed[:, 2]))       # inf flags survive
    # a degenerate objective cannot flip a weighted selection
    from repro.search import scalarize
    s01 = ObjectiveScales.fit(values[:, :2])
    ranks = np.argsort(scalarize(values[:, :2], [1.0, 1.0], scales=s01))
    assert ranks.tolist() == [0, 1, 2]
    # degenerate column mixed with one infeasible cell: offset comes from
    # the finite entries, the constant still normalizes to 0
    mixed = np.array([[5.0], [5.0], [np.inf]])
    s2 = ObjectiveScales.fit(mixed)
    out = s2.apply(mixed)
    assert out[0, 0] == 0.0 and out[1, 0] == 0.0 and np.isinf(out[2, 0])


def test_scales_fit_empty_sample_raises():
    with pytest.raises(ValueError, match="empty"):
        ObjectiveScales.fit(np.zeros((0, 2)))


# -- ε-constraint selection ---------------------------------------------------

@given(value_matrices())
@settings(**SETTINGS)
def test_epsilon_constraint_uncapped_is_argmin(inst):
    """ε = ∞ on every other objective (caps=None) reduces EXACTLY to the
    single-objective argmin over the minimized column — the property the
    serving layer's ε-constraint rank mode leans on."""
    from repro.search import epsilon_constraint
    values, _ = inst
    for k in range(values.shape[1]):
        idx, scores = epsilon_constraint(values, minimize=k)
        np.testing.assert_array_equal(scores, values[:, k])
        assert idx == int(np.argmin(values[:, k]))


@given(value_matrices())
@settings(**SETTINGS)
def test_epsilon_constraint_respects_caps(inst):
    """Capped selection: the winner satisfies every cap, beats every other
    feasible candidate on the minimized objective, and infeasible rows hold
    +inf.  Relaxing a cap never worsens the optimum (monotonicity)."""
    from repro.search import epsilon_constraint
    values, _ = inst
    names = tuple(f"o{k}" for k in range(values.shape[1]))
    cap = float(np.median(values[:, 1]))
    idx, scores = epsilon_constraint(values, minimize="o0",
                                     caps={"o1": cap}, names=names)
    feasible = values[:, 1] <= cap
    assert np.all(np.isinf(scores[~feasible]))
    np.testing.assert_array_equal(scores[feasible], values[feasible, 0])
    if feasible.any():
        assert feasible[idx]
        assert scores[idx] == values[feasible, 0].min()
    else:
        assert np.isinf(scores[idx])
    # monotonicity: a looser cap can only improve (or tie) the optimum
    _, loose = epsilon_constraint(values, minimize="o0",
                                  caps={"o1": cap * 2 + 1.0}, names=names)
    assert loose.min() <= scores.min() or np.isinf(scores.min())


def test_epsilon_constraint_validates_inputs():
    from repro.search import epsilon_constraint
    v = np.arange(6.0).reshape(3, 2)
    names = ("a", "b")
    with pytest.raises(ValueError, match="not among"):
        epsilon_constraint(v, minimize="zzz", names=names)
    with pytest.raises(ValueError, match="unknown objectives"):
        epsilon_constraint(v, minimize="a", caps={"zzz": 1.0}, names=names)
    with pytest.raises(ValueError, match="cannot cap the minimized"):
        epsilon_constraint(v, minimize="a", caps={"a": 1.0}, names=names)


def test_epsilon_constraint_from_score_grid_dispatch():
    """End to end over ObjectiveGrids from ONE score_grid dispatch: the
    ε-constraint pick is feasible on the worst-case envelope and optimal
    among feasible candidates — and an impossible cap reports infeasible
    (all-+inf scores) rather than raising."""
    from repro.search import epsilon_constraint
    rng = np.random.default_rng(7)
    g = linear_graph([1.0, 0.8, 0.5, 0.9])
    n_dev = 3
    fleets = []
    for _ in range(3):
        com = rng.uniform(0.1, 2.0, (n_dev, n_dev))
        com = (com + com.T) / 2
        np.fill_diagonal(com, 0.0)
        fleets.append(ExplicitFleet(com_cost=com))
    xs = [random_placement(4, np.ones((4, n_dev), bool), rng)
          for _ in range(8)]
    ev = BatchedEvaluator(g)
    from repro.sim import pack_fleets
    grids = ev.score_grid(pack_placements(xs), pack_fleets(fleets),
                          objectives=OBJ3)
    values = candidate_values(grids, "worst")
    cap = float(np.median(values[:, 1]))
    idx, scores = epsilon_constraint(grids, minimize="latency_f",
                                     caps={"network_movement": cap})
    assert values[idx, 1] <= cap
    feas = values[:, 1] <= cap
    assert scores[idx] == values[feas, 0].min()
    _, none = epsilon_constraint(grids, minimize="latency_f",
                                 caps={"network_movement": -1.0})
    assert np.all(np.isinf(none))
