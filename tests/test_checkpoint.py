"""Checkpoint/restart + fault tolerance: atomicity, keep-N GC, and a full
kill→resume cycle of the trainer driver (simulated node failure)."""

import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import (available_steps, latest_step,
                                      restore_checkpoint, save_checkpoint)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(4.0), "count": jnp.int32(3)}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 10, s, extra={"step": 10, "pipeline": {"cursor": 99, "seed": 0}})
    target = jax.tree.map(jnp.zeros_like, s)
    restored, extra = restore_checkpoint(tmp_path, 10, target)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["pipeline"]["cursor"] == 99


def test_keep_n_gc(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, step, s, keep=2)
    assert available_steps(tmp_path) == [4, 5]
    assert latest_step(tmp_path) == 5


def test_restore_rejects_shape_mismatch(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, 1, {"w": jnp.zeros((5,))})


def test_tmp_dir_never_published(tmp_path):
    """A leftover .tmp dir (crash mid-write) is not listed as a checkpoint."""
    save_checkpoint(tmp_path, 1, _state())
    (tmp_path / "step_2.tmp").mkdir()
    (tmp_path / "step_2.tmp" / "garbage").write_text("x")
    assert available_steps(tmp_path) == [1]


@pytest.mark.slow
def test_kill_and_resume_trainer(tmp_path):
    """Full fault-tolerance cycle: trainer dies at step 6 (simulated node
    failure), restarts with --resume, continues from checkpoint 5 and
    produces the SAME final params as an uninterrupted run (exact replay:
    deterministic data cursor + restored optimizer state)."""
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/tmp"}
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "olmo-1b", "--smoke", "--batch", "2", "--seq", "16",
              "--ckpt-every", "5", "--lr", "1e-3"]
    ck_a = tmp_path / "a"
    r = subprocess.run(common + ["--steps", "10", "--ckpt-dir", str(ck_a),
                                 "--die-at-step", "6"],
                       env=env, capture_output=True, text=True)
    assert r.returncode == 13, r.stderr  # simulated failure
    assert latest_step(ck_a) == 5
    r = subprocess.run(common + ["--steps", "10", "--ckpt-dir", str(ck_a),
                                 "--resume"],
                       env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert "resumed from step 5" in r.stdout
    assert latest_step(ck_a) == 10

    ck_b = tmp_path / "b"
    r = subprocess.run(common + ["--steps", "10", "--ckpt-dir", str(ck_b)],
                       env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr

    import numpy as np
    a = np.load(ck_a / "step_10" / "arrays.npz")
    b = np.load(ck_b / "step_10" / "arrays.npz")
    assert set(a.files) == set(b.files)
    for f in a.files:
        np.testing.assert_allclose(a[f], b[f], atol=1e-5,
                                   err_msg=f"leaf {f} diverged after resume")
