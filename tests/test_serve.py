"""repro.serve: coalescing correctness (bitwise parity with direct
score_grid, padding non-leak, cross-tenant merging), streaming, typed
admission verdicts, and per-kind post-processing parity with the decision
layer."""

import numpy as np
import pytest

from repro.core import (CostConfig, DQCoupling, ExplicitFleet, ObjectiveSet,
                        random_dag, random_placement)
from repro.search import (epsilon_constraint, joint_dq_scores, pareto_front,
                          robust_select, split_dq_term)
from repro.serve import (AdmissionConfig, Admitted, Degraded, Rejected,
                         QueryResult, ResultChunk, WhatIfQuery,
                         WhatIfService, fleet_digest, next_pow2, pad_rows)
from repro.sim import BatchedEvaluator, fresh_cache, pack_fleets, \
    pack_placements

RELAXED = AdmissionConfig(p99_budget_s=1e6)     # never refuse
OBJ2 = ObjectiveSet.from_weights(latency_f=1.0, network_movement=0.05)


def _setup(seed=0, n_ops=5, n_dev=4, n_fleets=3):
    rng = np.random.default_rng(seed)
    g = random_dag(n_ops, edge_prob=0.6, rng=rng)
    fleets = []
    for _ in range(n_fleets):
        com = rng.uniform(0.1, 3.0, (n_dev, n_dev))
        com = (com + com.T) / 2
        np.fill_diagonal(com, 0.0)
        fleets.append(ExplicitFleet(com_cost=com))
    coms = np.asarray(pack_fleets(fleets))

    def placements(n):
        return np.stack([
            random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng)
            for _ in range(n)]).astype(np.float32)

    return g, coms, placements


def _result(msgs, qid):
    (res,) = [m for m in msgs
              if isinstance(m, QueryResult) and m.query_id == qid]
    return res


def test_interleaved_tenants_bitwise_parity():
    """The core coalescing contract: many tenants, different row counts,
    different dq (scalar AND per-scenario) and β, all merged into shared
    padded dispatches — every tenant's scores are BITWISE what a direct
    dedicated score_grid call returns, and exactly their own rows (no
    padding, no neighbor rows leak)."""
    g, coms, placements = _setup()
    svc = WhatIfService(g, admission=RELAXED)
    fid = svc.register_fleet("anyone", coms)
    S = coms.shape[0]
    queries = [
        ("alice", placements(7), 0.3, 0.7),
        ("bob", placements(3), 0.0, 0.0),
        ("carol", placements(11), np.linspace(0.1, 0.8, S), 1.3),
        ("alice", placements(2), 0.9, 0.2),
    ]
    tickets = [(t, svc.submit(t, fid, WhatIfQuery(
        kind="score", placements=x, dq=dq, beta=beta)))
        for t, x, dq, beta in queries]
    assert all(isinstance(tk.admission, Admitted) for _, tk in tickets)
    svc.drain()
    mail = {t: svc.poll(t) for t in {"alice", "bob", "carol"}}
    ev = BatchedEvaluator.shared(g)
    for (tenant, x, dq, beta), (_, tk) in zip(queries, tickets):
        res = _result(mail[tenant], tk.query_id)
        direct = np.asarray(ev.score_grid(x, coms, dq=dq, beta=beta),
                            dtype=np.float32)
        assert res.scores.shape == (S, x.shape[0])
        np.testing.assert_array_equal(res.scores, direct)


def test_chunking_streams_partials_and_pads_safely():
    """max_chunk_rows smaller than the super-batch: queries stream as
    multiple ResultChunks whose offsets tile [0, P) exactly, concatenate
    to the final scores, and padded buckets never leak rows."""
    g, coms, placements = _setup()
    svc = WhatIfService(g, admission=RELAXED, max_chunk_rows=8)
    fid = svc.register_fleet("t", coms)
    x = placements(13)              # spans 2 chunks: 8 + 5 (padded to 8)
    tk = svc.submit("t", fid, WhatIfQuery(kind="score", placements=x,
                                          dq=0.4, beta=0.6))
    svc.drain()
    msgs = svc.poll("t")
    chunks = [m for m in msgs if isinstance(m, ResultChunk)]
    res = _result(msgs, tk.query_id)
    assert [c.offset for c in chunks] == [0, 8]
    assert [c.rows for c in chunks] == [8, 5]
    np.testing.assert_array_equal(
        np.concatenate([c.scores for c in chunks], axis=1), res.scores)
    direct = np.asarray(
        BatchedEvaluator.shared(g).score_grid(x, coms, dq=0.4, beta=0.6),
        dtype=np.float32)
    np.testing.assert_array_equal(res.scores, direct)


def test_pad_rows_contract():
    x = np.ones((3, 2, 4), np.float32)
    padded = pad_rows(x, 8)
    assert padded.shape == (8, 2, 4)
    np.testing.assert_array_equal(padded[3:], np.repeat(x[-1:], 5, axis=0))
    assert pad_rows(x, 3) is x
    with pytest.raises(ValueError, match="exceeds"):
        pad_rows(x, 2)
    assert [next_pow2(n) for n in (1, 2, 3, 9)] == [1, 2, 4, 16]


def test_equal_fleets_coalesce_across_tenants():
    """Two tenants registering EQUAL packs get the same fleet id (content
    digest), and their queries ride one dispatch — while a different
    objective set forks the coalesce key."""
    g, coms, placements = _setup()
    svc = WhatIfService(g, admission=RELAXED)
    fa = svc.register_fleet("a", coms.copy())
    fb = svc.register_fleet("b", coms.copy())
    assert fa == fb == svc.register_fleet("c", coms)
    assert svc.register_fleet("a", coms, objectives=OBJ2) != fa
    svc.submit("a", fa, WhatIfQuery(kind="score", placements=placements(4)))
    svc.submit("b", fb, WhatIfQuery(kind="score", placements=placements(4),
                                    dq=0.5, beta=2.0))
    svc.drain()
    snap = svc.stats.snapshot()
    assert len(snap["buckets"]) == 1          # ONE coalesced dispatch
    assert snap["buckets"][0]["dispatches"] == 1
    assert snap["buckets"][0]["queries"] == 2
    assert snap["buckets"][0]["rows"] == 8


def test_multi_objective_grids_parity():
    """Multi-objective serving: raw per-objective grids are bitwise equal
    to a direct dq=0 dispatch; the dq-finished scalarization matches the
    device's own finish to float32 resolution (the recombination crosses
    float64 host math, so bitwise is only guaranteed for the raw grids and
    the single-objective path)."""
    g, coms, placements = _setup()
    svc = WhatIfService(g, admission=RELAXED)
    fid = svc.register_fleet("t", coms, objectives=OBJ2)
    x = placements(6)
    dq, beta = 0.35, 0.8
    tk = svc.submit("t", fid, WhatIfQuery(kind="score", placements=x,
                                          dq=dq, beta=beta))
    svc.drain()
    res = _result(svc.poll("t"), tk.query_id)
    ev = BatchedEvaluator.shared(g)
    raw = ev.score_grid(x, coms, objectives=OBJ2)      # dq=0 raw dispatch
    for name in OBJ2.names:
        want = np.asarray(raw.grids[name], dtype=np.float32)
        if name == "latency_f":
            continue                # dq-finished below; raw parity via rest
        np.testing.assert_array_equal(res.grids[name], want)
    direct = ev.score_grid(x, coms, dq=dq, beta=beta, objectives=OBJ2)
    np.testing.assert_allclose(
        res.scores, np.asarray(direct.scalarized, dtype=np.float32),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        res.grids["latency_f"],
        np.asarray(direct.grids["latency_f"], dtype=np.float32),
        rtol=1e-6, atol=0)


def test_rank_pareto_joint_match_decision_layer():
    """Per-kind post-processing == applying the decision layer directly to
    the same served grids."""
    g, coms, placements = _setup()
    svc = WhatIfService(g, admission=RELAXED)
    fid = svc.register_fleet("t", coms)
    fid_m = svc.register_fleet("t", coms, objectives=OBJ2)
    x = placements(9)
    dqv = np.linspace(0.0, 0.9, 7)
    coupling = DQCoupling(cap0=np.full(coms.shape[1], 3.0),
                          load=np.full(coms.shape[1], 2.0))
    t_rank = svc.submit("t", fid, WhatIfQuery(
        kind="rank", placements=x, dq=0.2, beta=0.5, top_k=4))
    t_par = svc.submit("t", fid_m, WhatIfQuery(kind="pareto", placements=x))
    t_joint = svc.submit("t", fid, WhatIfQuery(
        kind="joint", placements=x, beta=0.9, dq_values=dqv,
        coupling=coupling))
    svc.drain()
    msgs = svc.poll("t")
    ev = BatchedEvaluator.shared(g)

    rank = _result(msgs, t_rank.query_id)
    best, worst = robust_select(np.asarray(
        ev.score_grid(x, coms, dq=0.2, beta=0.5), dtype=np.float32))
    np.testing.assert_array_equal(rank.worst, worst)
    assert rank.top[0] == best and len(rank.top) == 4

    par = _result(msgs, t_par.query_id)
    want_front = pareto_front(ev.score_grid(x, coms, objectives=OBJ2))
    np.testing.assert_array_equal(par.front.indices, want_front.indices)

    joint = _result(msgs, t_joint.query_id)
    lat, rest, w_lat = split_dq_term(
        np.asarray(ev.score_grid(x, coms), dtype=np.float32))
    from repro.search import dq_caps_mask
    want_scores, want_idx = joint_dq_scores(
        lat, dqv, 0.9, rest=rest, w_lat=w_lat,
        feasible=dq_caps_mask(x, dqv, coupling))
    np.testing.assert_array_equal(joint.scores, want_scores)
    np.testing.assert_array_equal(joint.dq_idx, want_idx)
    assert joint.best == robust_select(want_scores)[0]


def test_eps_constraint_rank_and_infeasible_flag():
    g, coms, placements = _setup()
    svc = WhatIfService(g, admission=RELAXED)
    fid = svc.register_fleet("t", coms, objectives=OBJ2)
    x = placements(8)
    t_ok = svc.submit("t", fid, WhatIfQuery(
        kind="rank", placements=x, minimize="latency_f",
        eps_caps={"network_movement": 1e9}, top_k=2))
    t_bad = svc.submit("t", fid, WhatIfQuery(
        kind="rank", placements=x, minimize="latency_f",
        eps_caps={"network_movement": -1.0}))
    svc.drain()
    msgs = svc.poll("t")
    ok = _result(msgs, t_ok.query_id)
    grids = BatchedEvaluator.shared(g).score_grid(x, coms, objectives=OBJ2)
    want_idx, _ = epsilon_constraint(grids, "latency_f",
                                     {"network_movement": 1e9})
    assert not ok.infeasible and ok.top[0] == want_idx
    bad = _result(msgs, t_bad.query_id)
    assert bad.infeasible and np.all(np.isinf(bad.worst))


def test_admission_rejects_and_degrades_typed():
    """A zero-ish budget rejects with the price it refused; a budget that
    fits a prefix degrades: the ticket says keep_rows/actions, and the
    result covers exactly the kept prefix (bitwise)."""
    g, coms, placements = _setup()
    x = placements(64)
    with fresh_cache():             # pricer must not see a warm cache
        svc = WhatIfService(g, admission=AdmissionConfig(
            p99_budget_s=0.0, allow_degrade=False))
        fid = svc.register_fleet("t", coms)
        verdict = svc.submit("t", fid, WhatIfQuery(kind="score",
                                                   placements=x))
        assert isinstance(verdict, Rejected)
        assert verdict.predicted_s > verdict.budget_s == 0.0
        assert "exceeds p99 budget" in verdict.reason
        assert svc.stats.snapshot()["admission"]["rejected"] == 1

    with fresh_cache():
        svc = WhatIfService(g, admission=AdmissionConfig(p99_budget_s=1e6))
        fid = svc.register_fleet("t", coms)
        # warm once so the pricer is calibrated on real dispatch time,
        # then set the budget to ~45% of the 64-row price: degrade land
        svc.submit("t", fid, WhatIfQuery(kind="score", placements=x))
        svc.drain()
        svc.poll("t")
        price = svc._fleets[fid].pricer.price_s(coms.shape[0], 64)
        svc.admission = AdmissionConfig(p99_budget_s=price * 0.45,
                                        min_rows=8)
        tk = svc.submit("t", fid, WhatIfQuery(kind="score", placements=x,
                                              dq=0.3, beta=0.7))
        assert isinstance(tk, type(tk)) and isinstance(tk.admission,
                                                       Degraded)
        assert "subsample_candidates" in tk.admission.actions
        assert tk.rows == tk.admission.keep_rows < 64
        svc.drain()
        res = _result(svc.poll("t"), tk.query_id)
        direct = np.asarray(BatchedEvaluator.shared(g).score_grid(
            x[:tk.rows], coms, dq=0.3, beta=0.7), dtype=np.float32)
        np.testing.assert_array_equal(res.scores, direct)
        assert res.degraded is tk.admission
        assert svc.stats.snapshot()["admission"]["degraded"] == 1


def test_joint_degrade_coarsens_dq_grid():
    g, coms, placements = _setup()
    with fresh_cache():
        svc = WhatIfService(g, admission=AdmissionConfig(p99_budget_s=1e6))
        fid = svc.register_fleet("t", coms)
        x = placements(32)
        svc.submit("t", fid, WhatIfQuery(kind="score", placements=x))
        svc.drain(); svc.poll("t")
        price = svc._fleets[fid].pricer.price_s(coms.shape[0], 32)
        svc.admission = AdmissionConfig(p99_budget_s=price * 0.45,
                                        min_rows=4, degrade_dq_steps=3)
        tk = svc.submit("t", fid, WhatIfQuery(
            kind="joint", placements=x, beta=0.5,
            dq_values=np.linspace(0, 0.9, 11)))
        assert isinstance(tk.admission, Degraded)
        assert "coarsen_dq_grid" in tk.admission.actions
        assert tk.dq_steps == 3
        svc.drain()
        res = _result(svc.poll("t"), tk.query_id)
        assert res.dq_idx.max() <= 2


def test_fleet_digest_is_content_addressed():
    rng = np.random.default_rng(3)
    a = rng.uniform(0.1, 1.0, (2, 4, 4)).astype(np.float32)
    assert fleet_digest(a) == fleet_digest(a.copy())
    b = a.copy()
    b[0, 1, 2] += 1e-3
    assert fleet_digest(a) != fleet_digest(b)
    with pytest.raises(ValueError, match=r"\(S, V, V\)"):
        fleet_digest(np.zeros((3, 4)))


def test_submit_validation():
    g, coms, placements = _setup()
    svc = WhatIfService(g, admission=RELAXED)
    fid = svc.register_fleet("t", coms)
    with pytest.raises(ValueError, match="kind"):
        WhatIfQuery(kind="nope", placements=placements(2))
    with pytest.raises(ValueError, match="dq_values"):
        WhatIfQuery(kind="joint", placements=placements(2))
    with pytest.raises(ValueError, match="ObjectiveSet"):
        svc.submit("t", fid, WhatIfQuery(kind="pareto",
                                         placements=placements(2)))
    with pytest.raises(ValueError, match="devices"):
        svc.submit("t", fid, WhatIfQuery(
            kind="score", placements=np.ones((2, 5, 9), np.float32)))
