"""The V-blocked edge-latency kernels against the float64 oracle and the
single-tile kernels they replaced: padding/blocking edge cases (V, E, R not
multiples of lane/block sizes, E ∈ {0, 1}, shared vs per-scenario com),
≤1e-5 oracle parity in interpret mode, exact parity at small V, and
block-shape invariance."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.edge_latency import (
    LANE,
    SUBLANE,
    block_geometry,
    edge_latency_pallas,
    edge_latency_pallas_single_tile,
    edge_latency_structured_pallas,
    edge_latency_structured_pallas_single_tile,
)

REL = 1e-5


def _dense_oracle(xi, xj, com):
    """float64 numpy reference: max_u xi · (com @ xj)_u, com (Bc, V, V)."""
    xi = np.asarray(xi, np.float64)
    xj = np.asarray(xj, np.float64)
    com = np.broadcast_to(np.asarray(com, np.float64),
                          (xi.shape[0],) + np.asarray(com).shape[1:])
    t = np.einsum("buv,bev->beu", com, xj)
    return np.max(xi * t, axis=-1)


def _structured_oracle(xi, xj, mass, a, corr):
    """float64 reference: max_u xi · (mass @ a + corr·xj)_u."""
    xi = np.asarray(xi, np.float64)
    xj = np.asarray(xj, np.float64)
    B = xi.shape[0]
    a64 = np.broadcast_to(np.asarray(a, np.float64),
                          (B,) + np.asarray(a).shape[1:])
    corr64 = np.broadcast_to(np.asarray(corr, np.float64),
                             (B,) + np.asarray(corr).shape[1:])
    t = np.einsum("ber,bru->beu", np.asarray(mass, np.float64), a64)
    return np.max(xi * (t + corr64 * xj), axis=-1)


def _rel_err(got, want):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-12)


def _dense_inputs(rng, B, E, V, shared_com):
    xi = jnp.asarray(rng.standard_normal((B, E, V)), jnp.float32)
    xj = jnp.asarray(rng.standard_normal((B, E, V)), jnp.float32)
    bc = 1 if shared_com else B
    com = jnp.asarray(rng.standard_normal((bc, V, V)), jnp.float32)
    return xi, xj, com


def _structured_inputs(rng, B, E, V, R, shared):
    xi = jnp.asarray(rng.standard_normal((B, E, V)), jnp.float32)
    xj = jnp.asarray(rng.standard_normal((B, E, V)), jnp.float32)
    mass = jnp.asarray(rng.standard_normal((B, E, R)), jnp.float32)
    bc = 1 if shared else B
    a = jnp.asarray(rng.standard_normal((bc, R, V)), jnp.float32)
    corr = jnp.asarray(rng.standard_normal((bc, 1, V)), jnp.float32)
    return xi, xj, mass, a, corr


# -- geometry -----------------------------------------------------------------

def test_geometry_rounds_blocks_and_pads_axes():
    g = block_geometry("dense", E=33, V=300, R=None,
                       block_edges=16, block_v=200)
    assert g.bv % LANE == 0 and g.be % SUBLANE == 0
    assert g.v_pad % g.bv == 0 and g.v_pad >= 300
    assert g.e_pad % g.be == 0 and g.e_pad >= 33
    assert g.n_u == g.v_pad // g.bv and g.n_v == g.n_u


def test_geometry_clamps_oversized_blocks_to_padded_axis():
    g = block_geometry("dense", E=5, V=129, R=None,
                       block_edges=512, block_v=4096)
    assert g.bv == ((129 + LANE - 1) // LANE) * LANE  # one V tile
    assert g.be == SUBLANE  # E=5 rounds to one sublane tile
    assert g.n_e == g.n_u == g.n_v == 1


def test_geometry_structured_pads_r_to_lane():
    g = block_geometry("structured", E=12, V=300, R=3,
                       block_edges=128, block_v=512)
    assert g.r_pad == LANE and g.n_v == 1


def test_geometry_rejects_bad_inputs():
    with pytest.raises(ValueError):
        block_geometry("diag", 4, 64, None, 128, 512)
    with pytest.raises(ValueError):
        block_geometry("dense", 0, 64, None, 128, 512)
    with pytest.raises(ValueError):
        block_geometry("structured", 4, 64, None, 128, 512)


# -- dense oracle parity ------------------------------------------------------

@pytest.mark.parametrize("V", [7, 129, 300])
@pytest.mark.parametrize("shared_com", [True, False])
def test_dense_oracle_parity_odd_V(V, shared_com):
    """≤1e-5 float64-oracle parity at V not divisible by the lane width
    (and at V=300, not divisible by the block either)."""
    rng = np.random.default_rng(V)
    xi, xj, com = _dense_inputs(rng, B=2, E=5, V=V, shared_com=shared_com)
    got = edge_latency_pallas(xi, xj, com, block_edges=16, block_v=128,
                              interpret=True)
    assert _rel_err(got, _dense_oracle(xi, xj, com)) <= REL


@pytest.mark.parametrize("E", [1, 33, 130])
def test_dense_oracle_parity_odd_E(E):
    """E not a multiple of the sublane/block size still pads and reduces
    correctly (padded edge rows are sliced off, padded u masked to -inf)."""
    rng = np.random.default_rng(E)
    xi, xj, com = _dense_inputs(rng, B=2, E=E, V=129, shared_com=True)
    got = edge_latency_pallas(xi, xj, com, block_edges=16, block_v=128,
                              interpret=True)
    assert got.shape == (2, E)
    assert _rel_err(got, _dense_oracle(xi, xj, com)) <= REL


def test_dense_empty_edge_set_returns_empty():
    xi = jnp.zeros((3, 0, 64), jnp.float32)
    com = jnp.zeros((1, 64, 64), jnp.float32)
    out = edge_latency_pallas(xi, xi, com, interpret=True)
    assert out.shape == (3, 0)


def test_dense_negative_operands_padded_columns_masked():
    """All-negative operands: a padded u column contributing 0 would win
    the max if it weren't masked to -inf."""
    rng = np.random.default_rng(7)
    V = 130  # pads 126 fake u columns at bv=256
    xi = -jnp.asarray(rng.uniform(0.5, 1.0, (2, 4, V)), jnp.float32)
    xj = jnp.asarray(rng.uniform(0.5, 1.0, (2, 4, V)), jnp.float32)
    com = jnp.asarray(rng.uniform(0.5, 1.0, (1, V, V)), jnp.float32)
    got = edge_latency_pallas(xi, xj, com, interpret=True)
    want = _dense_oracle(xi, xj, com)
    assert float(np.asarray(got).max()) < 0
    assert _rel_err(got, want) <= REL


def test_dense_rejects_mismatched_com_batch():
    xi = jnp.zeros((3, 2, 64), jnp.float32)
    com = jnp.zeros((2, 64, 64), jnp.float32)
    with pytest.raises(ValueError):
        edge_latency_pallas(xi, xi, com, interpret=True)


# -- structured oracle parity -------------------------------------------------

@pytest.mark.parametrize("R", [3, 5, 130])
@pytest.mark.parametrize("shared", [True, False])
def test_structured_oracle_parity_odd_R(R, shared):
    """R not a multiple of the lane width (including R > LANE) pads with
    exact-zero rows; ≤1e-5 oracle parity at odd V too."""
    rng = np.random.default_rng(R)
    xi, xj, mass, a, corr = _structured_inputs(rng, B=2, E=5, V=300, R=R,
                                               shared=shared)
    got = edge_latency_structured_pallas(xi, xj, mass, a, corr,
                                         block_edges=16, block_v=128,
                                         interpret=True)
    assert _rel_err(got, _structured_oracle(xi, xj, mass, a, corr)) <= REL


@pytest.mark.parametrize("E", [1, 33])
def test_structured_oracle_parity_odd_E(E):
    rng = np.random.default_rng(E + 100)
    xi, xj, mass, a, corr = _structured_inputs(rng, B=2, E=E, V=129, R=8,
                                               shared=True)
    got = edge_latency_structured_pallas(xi, xj, mass, a, corr,
                                         interpret=True)
    assert got.shape == (2, E)
    assert _rel_err(got, _structured_oracle(xi, xj, mass, a, corr)) <= REL


def test_structured_empty_edge_set_returns_empty():
    xi = jnp.zeros((2, 0, 64), jnp.float32)
    mass = jnp.zeros((2, 0, 4), jnp.float32)
    a = jnp.zeros((1, 4, 64), jnp.float32)
    corr = jnp.zeros((1, 1, 64), jnp.float32)
    out = edge_latency_structured_pallas(xi, xi, mass, a, corr,
                                         interpret=True)
    assert out.shape == (2, 0)


def test_structured_rejects_mismatched_scenario_batch():
    xi = jnp.zeros((3, 2, 64), jnp.float32)
    mass = jnp.zeros((3, 2, 4), jnp.float32)
    a = jnp.zeros((2, 4, 64), jnp.float32)
    corr = jnp.zeros((2, 1, 64), jnp.float32)
    with pytest.raises(ValueError):
        edge_latency_structured_pallas(xi, xi, mass, a, corr,
                                       interpret=True)


# -- exact parity vs the single-tile kernels ----------------------------------

@pytest.mark.parametrize("shared_com", [True, False])
def test_dense_blocked_exact_vs_single_tile_small_V(shared_com):
    """At V within one lane-aligned tile the blocked kernel performs the
    IDENTICAL dot (appended zero columns add exact +0.0 in f32) and max —
    bitwise parity with the original single-tile kernel."""
    rng = np.random.default_rng(0)
    xi, xj, com = _dense_inputs(rng, B=2, E=5, V=64, shared_com=shared_com)
    blocked = np.asarray(edge_latency_pallas(xi, xj, com, interpret=True))
    single = np.asarray(edge_latency_pallas_single_tile(xi, xj, com,
                                                        interpret=True))
    np.testing.assert_array_equal(blocked, single)


@pytest.mark.parametrize("shared", [True, False])
def test_structured_blocked_exact_vs_single_tile_small_V(shared):
    rng = np.random.default_rng(1)
    xi, xj, mass, a, corr = _structured_inputs(rng, B=2, E=5, V=64, R=4,
                                               shared=shared)
    blocked = np.asarray(edge_latency_structured_pallas(
        xi, xj, mass, a, corr, interpret=True))
    single = np.asarray(edge_latency_structured_pallas_single_tile(
        xi, xj, mass, a, corr, interpret=True))
    np.testing.assert_array_equal(blocked, single)


# -- block-shape invariance ---------------------------------------------------

def test_dense_result_invariant_to_block_shape():
    """Different (block_edges, block_v) choices change the accumulation
    ORDER but not the value beyond f32 roundoff — the autotuner is free to
    pick any feasible config."""
    rng = np.random.default_rng(3)
    xi, xj, com = _dense_inputs(rng, B=2, E=33, V=300, shared_com=True)
    outs = [np.asarray(edge_latency_pallas(xi, xj, com, block_edges=be,
                                           block_v=bv, interpret=True))
            for be, bv in [(8, 128), (16, 256), (64, 512), (128, 1024)]]
    for other in outs[1:]:
        np.testing.assert_allclose(other, outs[0], rtol=1e-5, atol=1e-5)


def test_structured_result_invariant_to_block_shape():
    rng = np.random.default_rng(4)
    xi, xj, mass, a, corr = _structured_inputs(rng, B=2, E=17, V=300, R=5,
                                               shared=True)
    outs = [np.asarray(edge_latency_structured_pallas(
        xi, xj, mass, a, corr, block_edges=be, block_v=bv, interpret=True))
        for be, bv in [(8, 128), (16, 256), (64, 512)]]
    for other in outs[1:]:
        np.testing.assert_allclose(other, outs[0], rtol=1e-5, atol=1e-5)
