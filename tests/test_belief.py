"""Property battery for the belief layer (repro.belief): the learned prior
recovers planted ground truth from synthetic traces, the posterior variance
is monotone in observation count and re-inflates under age decay, the
featurization is identity-free (device reindexing permutes feature rows),
and zero-observation devices return EXACTLY the prior mean."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.belief import (BeliefState, device_features, fit_prior,
                          op_features, speed_percentile)
from repro.core.calibration import ReplayWindow
from repro.core.devices import ExplicitFleet
from repro.core.graph import Operator, OpGraph
from repro.sim import merge_tuples, training_tuples

SETTINGS = dict(max_examples=30, deadline=None)


# -- synthetic-trace harness ---------------------------------------------------

def _chain_graph() -> OpGraph:
    ops = [Operator("source", selectivity=1.0, out_bytes=4.0, work=1.0),
           Operator("map", selectivity=1.0, out_bytes=8.0, work=2.0),
           Operator("filter", selectivity=0.5, out_bytes=4.0, work=1.0)]
    return OpGraph(ops, [(0, 1), (1, 2)])


def _random_fleet(rng: np.random.Generator, v: int = 6) -> ExplicitFleet:
    com = rng.uniform(0.5, 2.0, (v, v))
    com = (com + com.T) / 2
    np.fill_diagonal(com, 0.0)
    speed = rng.uniform(0.5, 4.0, v)
    region = np.arange(v) // 2
    return ExplicitFleet(com_cost=com, speed=speed, region=region)


def _planted_degrade(fleet, slow_factor: float) -> np.ndarray:
    """Ground truth tied to a FEATURE (the bottom speed tier), not to device
    ids — the only kind of truth a transferable prior can learn."""
    pct = speed_percentile(np.asarray(fleet.effective_speed()))
    return np.where(pct < 1.0 / 3.0, slow_factor, 1.0)


def _synthetic_window(graph, fleet, d_true, sel_scale_true,
                      work_unit: float = 1e-3, t_ticks: int = 6,
                      rate: float = 64.0) -> ReplayWindow:
    """Forward-simulate the occupancy model: the busy series a fleet with
    planted slowdowns and selectivity drift would emit under a uniform
    placement — the (placement, fleet, observed-cost) tuples replay traces
    generate, without paying for an engine."""
    v = fleet.n_devices
    n_ops = graph.n_ops
    x = np.full((n_ops, v), 1.0 / v)
    rates = np.full(t_ticks, rate)
    sel_true = np.array([op.selectivity for op in graph.operators]) \
        * sel_scale_true
    rows_in = np.empty((t_ticks, n_ops))
    rows_out = np.empty((t_ticks, n_ops))
    for i in range(n_ops):
        parents = [a for a, b in graph.edges if b == i]
        rows_in[:, i] = rates if not parents \
            else np.sum([rows_out[:, a] for a in parents], axis=0)
        rows_out[:, i] = rows_in[:, i] * sel_true[i]
    wk = np.array([op.work for op in graph.operators])
    load = np.einsum("ti,iu->tu", rows_in * wk[None, :], x)
    speed = np.asarray(fleet.effective_speed(), dtype=np.float64)
    busy = work_unit * load * (d_true / speed)[None, :]
    return ReplayWindow(rates=rates, busy=busy,
                        observed_latency=busy.max(axis=1), xs=x,
                        op_rows_in=rows_in, op_rows_out=rows_out)


# -- satellite 1: the four required properties ---------------------------------

def test_prior_recovers_planted_degrade_and_selectivity():
    """Fit on synthetic traces from training fleets, predict a HELD-OUT
    fleet: the recovered slowdowns and selectivity scales match the planted
    ground truth within tolerance (the truth is a function of features, so
    transfer to unseen devices is exactly what is being tested)."""
    graph = _chain_graph()
    slow, sel_scale = 6.0, np.array([1.0, 1.0, 1.4])
    parts = []
    for seed in range(6):
        fleet = _random_fleet(np.random.default_rng(seed))
        d_true = _planted_degrade(fleet, slow)
        window = _synthetic_window(graph, fleet, d_true, sel_scale)
        parts.append(training_tuples(graph, fleet, window, work_unit=1e-3))
    corpus = merge_tuples(parts)
    assert corpus.n_device_rows > 0 and corpus.n_op_rows > 0
    prior = fit_prior(device_features=corpus.device_features,
                      device_log_degrade=corpus.device_log_degrade,
                      device_weights=corpus.device_weights,
                      op_features=corpus.op_features,
                      op_log_sel_scale=corpus.op_log_sel_scale,
                      op_weights=corpus.op_weights)
    held_out = _random_fleet(np.random.default_rng(99))
    pred = prior.predict_degrade(device_features(held_out))
    np.testing.assert_allclose(pred, _planted_degrade(held_out, slow),
                               rtol=0.15)
    pred_sel = prior.predict_sel_scale(op_features(graph))
    np.testing.assert_allclose(pred_sel, sel_scale, rtol=0.15)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4),
       st.floats(0.1, 0.9))
@settings(**SETTINGS)
def test_posterior_variance_monotone_and_decay(seed, n_rounds, decay):
    """More observations ⇒ posterior variance non-increasing (elementwise);
    age decay ⇒ variance increases again wherever evidence existed."""
    rng = np.random.default_rng(seed)
    fleet = _random_fleet(rng)
    b = BeliefState.from_fleet(fleet)
    var = b.posterior_var()
    np.testing.assert_array_equal(var, b.prior_var)  # zero obs = full prior
    for _ in range(n_rounds):
        w = rng.uniform(0.0, 2.0, fleet.n_devices)
        b.observe(rng.normal(size=fleet.n_devices), w)
        new_var = b.posterior_var()
        assert np.all(new_var <= var + 1e-15)
        assert np.all(new_var[w > 0] < var[w > 0])
        var = new_var
    b.decay(decay)
    decayed = b.posterior_var()
    assert np.all(decayed >= var - 1e-15)
    assert np.all(decayed[b.obs_count > 0] > var[b.obs_count > 0])


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_featurization_invariant_to_device_reindexing(seed):
    """Permuting devices permutes the feature rows by exactly the same
    permutation — features follow values (speed, region aggregates), never
    indices.  Within-region reindexing is the special case where the region
    vector is unchanged."""
    rng = np.random.default_rng(seed)
    fleet = _random_fleet(rng)
    perm = rng.permutation(fleet.n_devices)
    permuted = ExplicitFleet(
        com_cost=np.asarray(fleet.com_matrix())[np.ix_(perm, perm)],
        speed=np.asarray(fleet.effective_speed())[perm],
        region=np.asarray(fleet.region)[perm])
    np.testing.assert_allclose(device_features(permuted),
                               device_features(fleet)[perm], atol=1e-12)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_zero_observation_devices_return_exactly_prior_mean(seed):
    """Devices that were never observed return the prior mean EXACTLY
    (bitwise ==, not approximately) — partial observation of the fleet must
    not leak into the unobserved entries."""
    rng = np.random.default_rng(seed)
    fleet = _random_fleet(rng)
    v = fleet.n_devices
    b = BeliefState.from_fleet(fleet)
    b.prior_mean_log = rng.normal(size=v)  # arbitrary prior
    observed = rng.random(v) < 0.5
    w = np.where(observed, rng.uniform(0.5, 2.0, v), 0.0)
    b.observe(rng.normal(size=v), w)
    mean = b.posterior_mean_log()
    assert np.array_equal(mean[~observed], b.prior_mean_log[~observed])
    if observed.any():
        assert not np.array_equal(mean[observed],
                                  b.prior_mean_log[observed])
    var = b.posterior_var()
    assert np.array_equal(var[~observed], b.prior_var[~observed])


# -- supporting invariants -----------------------------------------------------

def test_belief_absolute_anchoring_across_commits():
    """Observations arrive as degrades RELATIVE to the believed fleet;
    cum_log anchors them absolutely, so the posterior mean is invariant to
    WHERE the commit boundary fell."""
    fleet = _random_fleet(np.random.default_rng(3))
    v = fleet.n_devices
    truth = np.log(np.linspace(1.0, 3.0, v))
    # one shot: the full slowdown observed against the base fleet
    one = BeliefState.from_fleet(fleet)
    one.observe(one.cum_log + truth, np.ones(v))
    # split: half the slowdown adopted (commit), the remainder then
    # observed RELATIVE to the committed state — the anchored observation
    # cum_log + log(rel) reconstructs the same absolute value
    split = BeliefState.from_fleet(fleet)
    first = np.exp(truth) ** 0.5
    split.commit(first)
    rel = np.exp(truth) / first
    split.observe(split.cum_log + np.log(rel), np.ones(v))
    np.testing.assert_allclose(split.est_log, one.est_log)
    np.testing.assert_allclose(split.posterior_mean_log(),
                               one.posterior_mean_log())


def test_sample_fleets_shrink_with_observation():
    """Posterior sampling spread collapses on well-observed devices and
    stays wide on never-observed ones — the property that makes belief
    sampling beat fixed jitter."""
    fleet = _random_fleet(np.random.default_rng(4))
    v = fleet.n_devices
    b = BeliefState.from_fleet(fleet)
    w = np.zeros(v)
    w[: v // 2] = 50.0  # first half heavily observed
    b.observe(np.zeros(v), w)
    rel = b.sample_degrade_rel(np.random.default_rng(0), 256)
    spread = np.log(rel).std(axis=0)
    assert spread[: v // 2].max() < spread[v // 2:].min()
    fleets = b.sample_fleets(fleet, np.random.default_rng(1), 3)
    assert len(fleets) == 3 and fleets[0].n_devices == v


def test_probe_candidates_target_uncertain_devices():
    from repro.search import probe_candidates

    n_ops, v = 3, 5
    x = np.zeros((n_ops, v))
    x[:, 0] = 1.0  # incumbent concentrates on device 0
    std = np.array([0.0, 0.0, 0.0, 0.5, 0.2])
    avail = np.ones((n_ops, v), bool)
    probes = probe_candidates(x, avail, std, epsilon=0.1, top_k=2)
    assert probes.shape == (2, n_ops, v)
    np.testing.assert_allclose(probes.sum(axis=2), 1.0)  # still placements
    # variant 0 probes only the most-uncertain device (3)
    assert probes[0][:, 3] == pytest.approx(0.1)
    assert probes[0][:, 4] == pytest.approx(0.0)
    # variant 1 splits ε over devices 3 and 4 ∝ their std
    assert probes[1][:, 3] == pytest.approx(0.1 * 0.5 / 0.7)
    assert probes[1][:, 4] == pytest.approx(0.1 * 0.2 / 0.7)
    # no uncertainty or no epsilon ⇒ empty batch
    assert probe_candidates(x, avail, np.zeros(v), 0.1).shape[0] == 0
    assert probe_candidates(x, avail, std, 0.0).shape[0] == 0
