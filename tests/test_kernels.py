"""Pallas kernel sweeps: shapes × dtypes, interpret=True vs the pure-jnp
oracles in kernels/ref.py (assignment requirement), plus hypothesis sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("B,S,H,D", [
    (1, 128, 1, 64), (2, 128, 4, 64), (1, 256, 2, 128),
    (2, 96, 3, 32), (1, 384, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, D, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (32, 128)])
def test_flash_attention_block_shape_invariance(bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 256, 2, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True,
                              bq=bq, bk=bk)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("b,L,H,P,N", [
    (2, 64, 8, 16, 16), (1, 128, 4, 32, 8), (2, 32, 2, 8, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, L, H, P, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(L + H), 6)
    x = jax.random.normal(ks[0], (b, L, H, P), dtype)
    B = (jax.random.normal(ks[1], (b, L, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[2], (b, L, N)) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[3], (b, L, H))) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    D = jax.random.normal(ks[5], (H,))
    y = ops.ssd_scan(x, B, C, dt, A, D, chunk=16, head_block=2,
                     interpret=True)
    y_exp, _ = ref.ssd_ref(x, B, C, dt, A, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_exp, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-3,
                               rtol=5e-2)


def test_ssd_scan_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    b, L, H, P, N = 1, 96, 4, 16, 8
    x = jax.random.normal(ks[0], (b, L, H, P))
    B = jax.random.normal(ks[1], (b, L, N)) * 0.5
    C = jax.random.normal(ks[2], (b, L, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, L, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    D = jax.random.normal(ks[5], (H,))
    # one batched device→host transfer for all chunkings, not one sync each
    outs = jax.device_get([ops.ssd_scan(x, B, C, dt, A, D, chunk=c,
                                        head_block=hb, interpret=True)
                           for c, hb in ((16, 4), (32, 2), (96, 1))])
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


@given(rows=st.integers(1, 300), d=st.sampled_from([64, 128, 256]),
       seed=st.integers(0, 2**30))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_property(rows, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (rows, d))
    w = jax.random.normal(k2, (d,))
    out = ops.rmsnorm(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.rmsnorm_ref(x, w)), atol=1e-5)


@given(st.integers(0, 2**30))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property_random_shapes(seed):
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 3))
    S = int(rng.choice([64, 128, 192, 256]))
    H = int(rng.integers(1, 4))
    D = int(rng.choice([32, 64]))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)
