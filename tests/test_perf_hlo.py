"""HLO analyzer: trip-count weighting, dot flops, collective accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf.hlo import analyze_module, parse_collectives
from repro.perf.roofline import compute_terms


def test_scan_flops_equal_unrolled():
    def scanned(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    def unrolled(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fs = analyze_module(jax.jit(scanned).lower(x, w).compile().as_text())
    fu = analyze_module(jax.jit(unrolled).lower(x, w).compile().as_text())
    assert fs.flops == pytest.approx(fu.flops, rel=1e-6)
    assert fs.flops == pytest.approx(8 * 2 * 128 ** 3, rel=0.01)


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b).sum()

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    s = analyze_module(jax.jit(f).lower(a, b).compile().as_text())
    assert s.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.02)


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, wi):
                return ci @ wi, None
            y, _ = jax.lax.scan(inner, c, w)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    s = analyze_module(jax.jit(f).lower(x, w).compile().as_text())
    assert s.flops == pytest.approx(5 * 3 * 2 * 64 ** 3, rel=0.02)


def test_collective_wire_model():
    from repro.perf.hlo import CollectiveStats
    hlo = """
HloModule test, is_scheduled=true

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%x), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    stats = parse_collectives(hlo)
    assert stats.counts["all-reduce"] == 1
    # ring: 2·B·(n−1)/n = 2·4096·0.75
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * 4096 * 0.75)


def test_roofline_terms_and_dominance():
    t = compute_terms(hlo_flops=197e12, hlo_bytes=819e9, wire_bytes=0.0,
                      chips=4, model_flops=4 * 197e12 * 0.5, per_device=True)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    assert t.useful_flops_fraction == pytest.approx(0.5)
    t2 = compute_terms(1e12, 1e9, 500e9, chips=4, model_flops=1e12)
    assert t2.dominant == "collective"
    assert t2.collective_s == pytest.approx(10.0)


# -- pinned against actually-compiled edge-latency kernels --------------------
# Costs of the paper's edge-latency contraction (B=2, E=6, V=8, R=4) as the
# V-BLOCKED kernels actually compile it: the wrappers pad V (and R) to the
# lane width and E to the sublane width (block_geometry is the single
# source of truth), so the dominant dot costs 2·B·e_pad·v_pad² (dense) /
# 2·B·e_pad·r_pad·v_pad (structured).  FLOPs are pinned to a tight band
# around that dot — exact equality would re-pin XLA's deterministic but
# version-dependent accounting of the elementwise mask/mul/max tail, which
# is O(1/v_pad) of the dot.  HBM bytes only as >= the PADDED I/O lower
# bound, since interpret-mode Pallas lowering adds interpreter traffic.

_B, _E, _V, _R = 2, 6, 8, 4


def _kernel_hlo(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


def _flops_band(dot: int, elementwise_outputs: int):
    """[dot, dot + slack]: the non-dot tail is a few ops per padded output
    element (mask compare, mul, max fold), bounded well below 8."""
    return dot, dot + 8 * elementwise_outputs


def test_dense_edge_latency_kernel_flops_pinned():
    from repro.kernels.edge_latency import (block_geometry,
                                            edge_latency_pallas)

    text = _kernel_hlo(
        lambda xi, xj, com: edge_latency_pallas(xi, xj, com, interpret=True),
        (_B, _E, _V), (_B, _E, _V), (1, _V, _V))
    s = analyze_module(text)
    g = block_geometry("dense", _E, _V, None, 128, 512)
    lo, hi = _flops_band(2 * _B * g.e_pad * g.v_pad * g.v_pad,
                         _B * g.e_pad * g.v_pad)
    assert lo <= s.flops <= hi
    # I/O floor: padded x_i + x_j + com + out, f32
    io_floor = 4 * (2 * _B * g.e_pad * g.v_pad + g.v_pad * g.v_pad
                    + _B * g.e_pad)
    assert s.hbm_bytes >= io_floor


def test_structured_edge_latency_kernel_flops_pinned():
    from repro.kernels.edge_latency import (block_geometry,
                                            edge_latency_structured_pallas)

    text = _kernel_hlo(
        lambda xi, xj, m, a, c: edge_latency_structured_pallas(
            xi, xj, m, a, c, interpret=True),
        (_B, _E, _V), (_B, _E, _V), (_B, _E, _R), (1, _R, _V), (1, 1, _V))
    s = analyze_module(text)
    g = block_geometry("structured", _E, _V, _R, 128, 512)
    lo, hi = _flops_band(2 * _B * g.e_pad * g.r_pad * g.v_pad,
                         _B * g.e_pad * g.v_pad)
    assert lo <= s.flops <= hi
    io_floor = 4 * (2 * _B * g.e_pad * g.v_pad + _B * g.e_pad * g.r_pad
                    + g.r_pad * g.v_pad + g.v_pad + _B * g.e_pad)
    assert s.hbm_bytes >= io_floor


def test_kernel_roofline_terms_finite():
    """The perf bridge's roofline on a real compiled module yields finite,
    positive step-time terms (the BENCH_* fields are well-defined)."""
    from repro.kernels.edge_latency import edge_latency_pallas

    text = _kernel_hlo(
        lambda xi, xj, com: edge_latency_pallas(xi, xj, com, interpret=True),
        (_B, _E, _V), (_B, _E, _V), (1, _V, _V))
    s = analyze_module(text)
    t = compute_terms(hlo_flops=s.flops, hlo_bytes=s.hbm_bytes,
                      wire_bytes=0.0, chips=1, model_flops=s.flops)
    assert t.step_time_s > 0 and np.isfinite(t.step_time_s)
    assert t.dominant in ("compute", "memory")
