"""HLO analyzer: trip-count weighting, dot flops, collective accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf.hlo import analyze_module, parse_collectives
from repro.perf.roofline import compute_terms


def test_scan_flops_equal_unrolled():
    def scanned(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    def unrolled(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fs = analyze_module(jax.jit(scanned).lower(x, w).compile().as_text())
    fu = analyze_module(jax.jit(unrolled).lower(x, w).compile().as_text())
    assert fs.flops == pytest.approx(fu.flops, rel=1e-6)
    assert fs.flops == pytest.approx(8 * 2 * 128 ** 3, rel=0.01)


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b).sum()

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    s = analyze_module(jax.jit(f).lower(a, b).compile().as_text())
    assert s.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.02)


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, wi):
                return ci @ wi, None
            y, _ = jax.lax.scan(inner, c, w)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    s = analyze_module(jax.jit(f).lower(x, w).compile().as_text())
    assert s.flops == pytest.approx(5 * 3 * 2 * 64 ** 3, rel=0.02)


def test_collective_wire_model():
    from repro.perf.hlo import CollectiveStats
    hlo = """
HloModule test, is_scheduled=true

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%x), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    stats = parse_collectives(hlo)
    assert stats.counts["all-reduce"] == 1
    # ring: 2·B·(n−1)/n = 2·4096·0.75
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * 4096 * 0.75)


def test_roofline_terms_and_dominance():
    t = compute_terms(hlo_flops=197e12, hlo_bytes=819e9, wire_bytes=0.0,
                      chips=4, model_flops=4 * 197e12 * 0.5, per_device=True)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    assert t.useful_flops_fraction == pytest.approx(0.5)
    t2 = compute_terms(1e12, 1e9, 500e9, chips=4, model_flops=1e12)
    assert t2.dominant == "collective"
    assert t2.collective_s == pytest.approx(10.0)
