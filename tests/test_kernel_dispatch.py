"""The backend dispatch policy and the VMEM-aware autotuner: flag
resolution (the serve/kernels interpret-default divergence fix), plan
construction, decision-table caching and persistence, and end-to-end
agreement of the consumers (BatchedEvaluator, WhatIfService) that now
route through one policy."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.core.graph import linear_graph
from repro.kernels import autotune, dispatch
from repro.kernels.autotune import KernelConfig, ShapeKey
from repro.obs.registry import MetricsRegistry, set_registry
from repro.sim.batched import BatchedEvaluator, pack_fleets, pack_placements
from repro.serve.service import WhatIfService


@pytest.fixture(autouse=True)
def _fresh_autotune_table():
    autotune.clear_table()
    yield
    autotune.clear_table()


@pytest.fixture
def metrics():
    reg = MetricsRegistry()
    reg.enabled = True
    old = obs.registry()
    set_registry(reg)
    yield reg
    set_registry(old)


def _counter_total(reg, name):
    return sum(r["value"] for r in reg.snapshot()
               if r["name"] == name and r["type"] == "counter")


# -- resolve_flags policy -----------------------------------------------------

def test_auto_flags_resolve_per_backend():
    assert dispatch.resolve_flags(None, None, backend="cpu") == (False, True)
    assert dispatch.resolve_flags(None, None, backend="tpu") == (True, False)


def test_explicit_pallas_on_cpu_keeps_interpret():
    assert dispatch.resolve_flags(True, None, backend="cpu") == (True, True)


def test_compiled_on_cpu_is_coerced_to_interpret(metrics):
    """The divergence fix's teeth: an explicit interpret=False on CPU
    cannot survive resolution (compiled Pallas can't lower there) and the
    coercion is observable."""
    assert dispatch.resolve_flags(True, False, backend="cpu") == (True, True)
    assert _counter_total(metrics, "kernels.dispatch.coerced") == 1


def test_interpret_on_accelerator_is_honored_but_counted(metrics):
    assert dispatch.resolve_flags(True, True, backend="tpu") == (True, True)
    assert _counter_total(
        metrics, "kernels.dispatch.interpret_on_accelerator") == 1


# -- plans --------------------------------------------------------------------

def test_plan_auto_on_cpu_is_xla():
    plan = dispatch.plan_edge_kernel("dense", 4, 24, 256, backend="cpu")
    assert plan.impl == "xla" and plan.interpret and plan.config is None


def test_plan_pallas_config_fits_vmem_budget():
    plan = dispatch.plan_edge_kernel("dense", 4, 24, 8192, use_pallas=True,
                                     backend="tpu")
    assert plan.impl == "pallas" and not plan.interpret
    assert autotune.vmem_bytes("dense", 24, 8192, None, plan.config) \
        <= autotune.VMEM_BUDGET_BYTES


def test_plan_pinned_blocks_bypass_autotuner():
    plan = dispatch.plan_edge_kernel("dense", 4, 24, 1024, use_pallas=True,
                                     backend="cpu", block_edges=64,
                                     block_v=256)
    assert plan.config == KernelConfig(block_edges=64, block_v=256)
    assert autotune.table_rows() == []  # no decision was recorded


def test_dispatch_routes_agree_numerically():
    rng = np.random.default_rng(0)
    xi = jnp.asarray(rng.standard_normal((2, 5, 300)), jnp.float32)
    xj = jnp.asarray(rng.standard_normal((2, 5, 300)), jnp.float32)
    com = jnp.asarray(rng.standard_normal((1, 300, 300)), jnp.float32)
    xla = np.asarray(dispatch.edge_latency(xi, xj, com, use_pallas=False))
    pal = np.asarray(dispatch.edge_latency(xi, xj, com, use_pallas=True,
                                           interpret=True))
    np.testing.assert_allclose(pal, xla, rtol=1e-5, atol=1e-5)


# -- autotuner ----------------------------------------------------------------

def test_candidates_all_fit_budget_and_dedupe():
    cands = autotune.candidate_configs("dense", 24, 300, None)
    assert cands
    geoms = set()
    from repro.kernels.edge_latency import block_geometry
    for c in cands:
        assert autotune.vmem_bytes("dense", 24, 300, None, c) \
            <= autotune.VMEM_BUDGET_BYTES
        g = block_geometry("dense", 24, 300, None, c.block_edges, c.block_v)
        assert (g.be, g.bv) not in geoms  # clamped duplicates dropped
        geoms.add((g.be, g.bv))


def test_cpu_model_prefers_fewer_grid_steps():
    """On CPU (interpret mode) per-step overhead dominates, so the model
    must rank a one-tile config above many small tiles."""
    best = autotune.rank("dense", 4, 24, 1024, backend="cpu")[0]
    from repro.kernels.edge_latency import block_geometry
    g = block_geometry("dense", 24, 1024, None, best.block_edges,
                       best.block_v)
    assert g.n_u * g.n_v == 1


def test_decision_is_cached_per_shape_key(metrics):
    a = autotune.get_config("dense", 4, 24, 1024, backend="cpu")
    b = autotune.get_config("dense", 4, 24, 1024, backend="cpu")
    assert a == b
    rows = [r for r in metrics.snapshot()
            if r["name"] == "kernels.autotune.decisions"]
    by_source = {r["labels"]["source"]: r["value"] for r in rows}
    assert by_source == {"analytic": 1, "table": 1}
    # B buckets to powers of two: B=3 shares B=4's entry
    assert autotune.get_config("dense", 3, 24, 1024, backend="cpu") == a
    assert len(autotune.table_rows()) == 1


def test_empirical_timer_overrides_analytic_ranking():
    ranked = autotune.rank("dense", 4, 24, 1024, backend="cpu")
    want = ranked[1]  # force a non-analytic winner
    cfg = autotune.get_config(
        "dense", 4, 24, 1024, backend="cpu",
        timer=lambda c: 0.0 if c == want else 1.0)
    assert cfg == want
    assert autotune.table_rows()[0]["source"] == "empirical"


def test_table_round_trips_through_json(tmp_path):
    autotune.get_config("dense", 4, 24, 1024, backend="cpu")
    autotune.get_config("structured", 2, 12, 131072, 8, backend="tpu")
    path = tmp_path / "table.json"
    autotune.save_table(path)
    rows_before = autotune.table_rows()
    autotune.clear_table()
    assert autotune.table_rows() == []
    assert autotune.load_table(path) == 2
    assert autotune.table_rows() == rows_before
    doc = json.loads(path.read_text())
    assert doc["version"] == 1 and len(doc["entries"]) == 2


def test_load_rejects_unknown_version(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError):
        autotune.load_table(path)


def test_shape_key_buckets_batch():
    assert ShapeKey.of("cpu", "dense", 3, 24, 64, None).b_bucket == 4
    assert ShapeKey.of("cpu", "dense", 4, 24, 64, None).b_bucket == 4
    assert ShapeKey.of("cpu", "dense", 5, 24, 64, None).b_bucket == 8
    assert ShapeKey.of("cpu", "dense", 1, 24, 64, None).b_bucket == 1


# -- consumers agree through the one policy -----------------------------------

def test_evaluator_and_service_resolve_to_same_flags():
    """The interpret-default divergence fix: a default-constructed service
    and a default-constructed shared evaluator land on the SAME concrete
    flags (and therefore the same executables / coalesce keys)."""
    g = linear_graph([1.0, 1.0, 1.0])
    svc = WhatIfService(g)
    ev = BatchedEvaluator.shared(g)
    assert isinstance(svc.use_pallas, bool)
    assert isinstance(svc.interpret, bool)
    assert (svc.use_pallas, svc.interpret) == (ev.use_pallas, ev.interpret)
    assert svc._ev is ev  # literally the same shared instance


def test_shared_memo_key_uses_resolved_flags():
    g = linear_graph([1.0, 1.0])
    auto = BatchedEvaluator.shared(g)
    concrete = BatchedEvaluator.shared(g, use_pallas=auto.use_pallas,
                                       interpret=auto.interpret)
    assert auto is concrete


def test_evaluator_pallas_path_matches_jnp_path():
    from repro.core import ExplicitFleet
    rng = np.random.default_rng(5)
    g = linear_graph([1.0, 0.5, 2.0, 1.5])
    com = rng.uniform(0.1, 2.0, (6, 6))
    com = (com + com.T) / 2
    np.fill_diagonal(com, 0.0)
    coms = pack_fleets([ExplicitFleet(com_cost=com)])
    xs = pack_placements([rng.uniform(0, 1, (4, 6)) for _ in range(3)])
    jnp_grid = np.asarray(BatchedEvaluator(g, use_pallas=False)
                          .score_grid(xs, coms))
    pal_grid = np.asarray(
        BatchedEvaluator(g, use_pallas=True, interpret=True)
        .score_grid(xs, coms))
    np.testing.assert_allclose(pal_grid, jnp_grid, rtol=1e-5, atol=1e-6)
