"""Fixture: triggers dtype-discipline (never imported, only linted)."""
import jax
import jax.numpy as jnp
import numpy as np


def promotes_to_f64(x):
    return jnp.asarray(x, jnp.float64)  # x64 is disabled: silent degrade


def constructs_f64(n):
    return jnp.zeros((n,), dtype="float64")


@jax.jit
def mixes_np_in_trace(x):
    return np.maximum(x, 0.0)  # numpy runs at trace time on tracers
