"""Fixture: triggers no-silent-retrace (never imported, only linted)."""
import jax


def lambda_captures_loop_var(xs):
    out = []
    for scale in xs:
        f = jax.jit(lambda v: v * scale)  # fresh compile per `scale`
        out.append(f(scale))
    return out


def rewraps_loop_invariant(fn, xs):
    total = 0.0
    for x in xs:
        g = jax.jit(fn)  # fn never changes: hoist the jit
        total += g(x)
    return total


def per_iteration_program(fns, xs):
    out = []
    for fn, x in zip(fns, xs):
        out.append(jax.jit(fn)(x))  # varies per iteration: warning
    return out
