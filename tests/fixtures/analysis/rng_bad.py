"""Fixture: triggers rng-discipline (never imported, only linted)."""
import jax
import numpy as np


def global_state_draw(n):
    return np.random.rand(n)  # mutates GLOBAL numpy rng state


def seeds_global_state():
    np.random.seed(0)


def key_reuse():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2,))
    b = jax.random.uniform(key, (2,))  # second draw from the same key
    return a, b
