"""Fixture: triggers pallas-constraints (never imported, only linted)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def float_grid(x, n):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(n / 8,),  # true division: non-integer step count
    )(x)


def arity_mismatch(x):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],  # 1 arg, 2-d grid
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i,)),  # rank-1 index
    )(x)


@jax.jit
def dynamic_shape(x):
    return jnp.nonzero(x)  # value-dependent output shape under jit
