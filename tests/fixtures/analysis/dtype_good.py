"""Fixture: dtype-discipline negatives — float32 twins, np outside traces."""
import jax
import jax.numpy as jnp
import numpy as np


def float32_twin(x):
    return jnp.asarray(x, jnp.float32)


@jax.jit
def pure_jnp(x):
    return jnp.maximum(x, 0.0)


def host_side_numpy(x):
    return np.maximum(np.asarray(x, dtype=np.float64), 0.0)
