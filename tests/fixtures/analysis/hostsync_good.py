"""Fixture: hidden-host-sync negatives — one batched transfer at the end."""
import jax
import jax.numpy as jnp
import numpy as np


def batched_transfer(f, xs):
    ys = [f(jnp.asarray(x)) for x in xs]
    host = jax.device_get(ys)  # ONE sync for the whole batch
    return [float(v) for v in host]


def host_only_loop(rows):
    total = 0.0
    for r in rows:
        total += float(np.sum(r))  # pure numpy: no device involved
    return total
