"""Fixture: triggers jit-purity (never imported, only linted)."""
import jax

TRACE_LOG = []


@jax.jit
def noisy(x):
    print("tracing", x)  # fires once per compile, not per call
    return x * 2


@jax.jit
def publishes(x):
    TRACE_LOG.append(1)  # mutation happens at trace time only
    return x + 1


class Model:
    @jax.jit
    def forward(self, x):
        self.calls = 1  # attribute write lost on cached executions
        return x
