"""Fixture: no-silent-retrace negatives — hoisted jits, traced args."""
import jax


def hoisted(fn, xs):
    g = jax.jit(fn)
    return [g(x) for x in xs]


def scale_as_argument(xs):
    f = jax.jit(lambda v, s: v * s)
    return [f(x, s) for x, s in zip(xs, xs)]
