"""Fixture: pallas-constraints negatives — padded // grids, matched specs."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def integer_grid(x, n, block):
    padded = n + (-n) % block
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(padded // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
    )(x)


@jax.jit
def static_masking(x):
    return jnp.where(x > 0, x, 0.0)  # three-arg form: static shape
