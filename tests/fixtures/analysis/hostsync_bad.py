"""Fixture: triggers hidden-host-sync (never imported, only linted)."""
import jax
import jax.numpy as jnp


def float_per_iteration(f, xs):
    total = 0.0
    for x in xs:
        total += float(f(jnp.asarray(x)))  # device→host sync per element
    return total


def item_on_device_value(xs):
    acc = jnp.zeros(())
    out = []
    for x in xs:
        out.append(acc.item())  # sync per iteration
    return out


def pull_per_iteration(ys):
    import numpy as np
    vals = jnp.asarray(ys)
    return [np.asarray(vals) for _ in range(3)]
