"""Fixture: rng-discipline negatives — explicit Generators, split keys."""
import jax
import numpy as np


def generator_draw(rng: np.random.Generator, n):
    return rng.random(n)


def fresh_generator(seed):
    return np.random.default_rng(seed).random(3)


def split_keys():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (2,))
    b = jax.random.uniform(k2, (2,))
    return a, b
