"""Fixture: jit-purity negatives — functional updates, local mutation."""
import jax
import jax.numpy as jnp


@jax.jit
def functional_update(x):
    y = x.at[0].set(0.0)  # .at[...] is pure: exempt
    return y.sum()


@jax.jit
def local_accumulator(xs):
    parts = []
    parts.append(xs.sum())  # trace-local list: fair game
    return parts[0]
