"""Hypothesis property tests for the cost model's invariants."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.core import (
    CostConfig,
    ExplicitFleet,
    RegionFleet,
    SmoothConfig,
    latency,
    latency_via_paths,
    make_latency_fn,
    network_movement,
    objective_F,
    random_dag,
    random_placement,
)

SETTINGS = dict(max_examples=40, deadline=None)


def _instance(draw, max_ops=6, max_dev=5):
    seed = draw(st.integers(0, 2**31 - 1))
    n_ops = draw(st.integers(2, max_ops))
    n_dev = draw(st.integers(2, max_dev))
    rng = np.random.default_rng(seed)
    g = random_dag(n_ops, edge_prob=0.5, rng=rng)
    com = rng.uniform(0.1, 3.0, (n_dev, n_dev))
    com = (com + com.T) / 2
    np.fill_diagonal(com, 0.0)
    fleet = ExplicitFleet(com_cost=com)
    x = random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng)
    return g, fleet, x, rng


@st.composite
def instances(draw):
    return _instance(draw)


@given(instances())
@settings(**SETTINGS)
def test_dp_equals_path_enumeration(inst):
    """The O(V+E) topological DP == the paper's explicit max over paths."""
    g, fleet, x, _ = inst
    assert latency(g, fleet, x) == pytest.approx(
        latency_via_paths(g, fleet, x), rel=1e-12)


@given(instances())
@settings(**SETTINGS)
def test_latency_nonnegative_and_finite(inst):
    g, fleet, x, _ = inst
    lat = latency(g, fleet, x)
    assert np.isfinite(lat) and lat >= 0.0


@given(instances())
@settings(**SETTINGS)
def test_monotone_in_com_cost(inst):
    """Uniformly slower links can never reduce latency."""
    g, fleet, x, _ = inst
    lat0 = latency(g, fleet, x)
    slower = ExplicitFleet(com_cost=fleet.com_cost * 2.0)
    assert latency(g, slower, x) >= lat0 - 1e-12


@given(instances())
@settings(**SETTINGS)
def test_scale_invariance(inst):
    """latency(c·comCost) == c·latency(comCost) (α=0): the model is linear
    in link costs."""
    g, fleet, x, _ = inst
    lat0 = latency(g, fleet, x)
    scaled = ExplicitFleet(com_cost=fleet.com_cost * 3.5)
    assert latency(g, scaled, x) == pytest.approx(3.5 * lat0, rel=1e-9)


@given(instances())
@settings(**SETTINGS)
def test_colocated_placement_has_zero_latency(inst):
    """Everything on one device (diagonal comCost = 0) ⇒ zero comm latency
    (the paper's model charges only network transfers)."""
    g, fleet, x, _ = inst
    n_dev = fleet.n_devices
    x1 = np.zeros_like(x)
    x1[:, 0] = 1.0
    assert latency(g, fleet, x1) == pytest.approx(0.0, abs=1e-12)


@given(instances())
@settings(**SETTINGS)
def test_alpha_monotone(inst):
    g, fleet, x, _ = inst
    lat0 = latency(g, fleet, x, CostConfig(alpha=0.0))
    lat1 = latency(g, fleet, x, CostConfig(alpha=0.5))
    assert lat1 >= lat0 - 1e-12


@given(instances())
@settings(**SETTINGS)
def test_F_monotone_in_dq_and_beta(inst):
    g, fleet, x, _ = inst
    lat = latency(g, fleet, x)
    for beta in (0.5, 1.0, 2.0):
        f_low = objective_F(lat, 0.2, beta)
        f_high = objective_F(lat, 0.8, beta)
        assert f_high <= f_low + 1e-12  # more DQ can only help F at fixed lat


@given(instances())
@settings(**SETTINGS)
def test_jax_twin_matches_numpy(inst):
    """Hard-max JAX model == f64 numpy oracle (to f32 precision)."""
    import jax.numpy as jnp

    g, fleet, x, _ = inst
    lat_np = latency(g, fleet, x)
    lat_fn = make_latency_fn(g, fleet)
    lat_jx = float(lat_fn(jnp.asarray(x)))
    assert lat_jx == pytest.approx(lat_np, rel=2e-5, abs=1e-6)


@given(instances())
@settings(**SETTINGS)
def test_smooth_upper_bounds_hard(inst):
    """logsumexp smoothing always upper-bounds the hard max."""
    import jax.numpy as jnp

    g, fleet, x, _ = inst
    hard = latency(g, fleet, x)
    smooth = float(make_latency_fn(g, fleet, SmoothConfig(temp=0.05))(
        jnp.asarray(x)))
    assert smooth >= hard - 1e-5


@given(instances())
@settings(**SETTINGS)
def test_region_fleet_matches_explicit(inst):
    """A RegionFleet and the ExplicitFleet of its materialized com matrix
    produce identical latencies."""
    g, _, x, rng = inst
    n_dev = x.shape[1]
    n_regions = rng.integers(1, n_dev + 1)
    region = rng.integers(0, n_regions, n_dev)
    inter = rng.uniform(0.1, 2.0, (n_regions, n_regions))
    inter = (inter + inter.T) / 2
    rf = RegionFleet(region=region, inter=inter, self_cost=0.0)
    ef = ExplicitFleet(com_cost=rf.com_matrix())
    assert latency(g, rf, x) == pytest.approx(latency(g, ef, x), rel=1e-12)


@given(instances())
@settings(**SETTINGS)
def test_network_movement_zero_when_colocated(inst):
    g, fleet, x, _ = inst
    x1 = np.zeros_like(x)
    x1[:, -1] = 1.0
    assert network_movement(g, fleet, x1) == pytest.approx(0.0, abs=1e-12)
