"""repro.obs telemetry layer: registry semantics, span split, trace schema,
recompile accounting, and the hard invariant that enabling telemetry never
changes numerics (same rng streams, same dispatch count, bitwise argmin)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import bench as obench
from repro.obs import jaxhooks, perfbridge
from repro.obs.spans import _fresh_trace


@pytest.fixture
def telemetry():
    """Enable telemetry against a fresh registry + trace buffer, restore
    the disabled default afterwards."""
    saved = obs.registry()
    reg = obs.MetricsRegistry(enabled=False)
    obs.set_registry(reg)
    with _fresh_trace():
        obs.enable()
        try:
            yield reg
        finally:
            obs.disable()
            obs.set_registry(saved)


# -- registry -----------------------------------------------------------------

def test_registry_disabled_by_default():
    assert not obs.enabled()
    # disabled spans are the shared no-op and the buffer never grows
    with _fresh_trace():
        with obs.span("x", a=1) as sp:
            sp.sync(jnp.ones(2))
        assert obs.trace_events() == []
        obs.counter_sample("c", 1.0)
        assert obs.trace_events() == []


def test_counter_gauge_histogram(telemetry):
    reg = telemetry
    reg.counter("c", path="dense").add(2)
    reg.counter("c", path="dense").add(3)
    reg.counter("c", path="structured").add(1)
    reg.gauge("g").set(7.5)
    h = reg.histogram("h", lo=1.0, growth=2.0, n_buckets=8)
    for v in (1.5, 3.0, 100.0):
        h.observe(v)
    assert reg.value("c", path="dense") == 5
    assert reg.value("c", path="structured") == 1
    assert reg.value("g") == 7.5
    row = h.row()
    assert row["count"] == 3 and row["max"] == 100.0
    assert sum(row["buckets"]) == 3
    names = {(r["name"], tuple(sorted(r["labels"].items())))
             for r in reg.snapshot()}
    assert ("c", (("path", "dense"),)) in names


# -- spans --------------------------------------------------------------------

def test_span_records_compile_and_execute_split(telemetry):
    @jax.jit
    def f(x):
        return (x @ x).sum()

    x = jnp.ones((64, 64))
    with obs.span("cold", n=64) as sp:
        sp.sync(f(x))
    assert sp.n_compiles >= 1
    assert sp.compile_s > 0
    assert sp.wall_s >= sp.compile_s
    with obs.span("warm", n=64) as sp2:
        sp2.sync(f(x))
    assert sp2.n_compiles == 0 and sp2.compile_s == 0.0
    evs = obs.trace_events()
    assert [e["name"] for e in evs if e["ph"] == "X"] == ["cold", "warm"]
    assert evs[0]["args"]["synced"] is True


def test_span_nesting_attributes_innermost(telemetry):
    @jax.jit
    def g(x):
        return x * 3.0

    with obs.span("outer") as outer:
        with obs.span("inner") as inner:
            inner.sync(g(jnp.ones(5)))
    assert inner.n_compiles >= 1
    assert outer.n_compiles == 0  # attributed to the innermost span only


def test_trace_export_roundtrip(tmp_path, telemetry):
    with obs.span("a", k=1):
        pass
    obs.counter_sample("drift", 0.25, extra=1.0)
    path = tmp_path / "t.trace.jsonl"
    n = obs.export_trace(path)
    assert n == 2
    # JSONL: every line is a standalone, schema-valid Chrome-trace event
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 2
    for line in lines:
        json.loads(line)
    back = obs.load_trace(path)
    assert [e["ph"] for e in back] == ["X", "C"]


def test_validate_events_rejects_malformed():
    with pytest.raises(ValueError, match="missing keys"):
        obs.validate_events([{"name": "x", "ph": "X"}])
    with pytest.raises(ValueError, match="dur"):
        obs.validate_events([{"name": "x", "ph": "X", "ts": 0.0,
                              "pid": 1, "tid": 1}])
    with pytest.raises(ValueError, match="numeric series"):
        obs.validate_events([{"name": "x", "ph": "C", "ts": 0.0,
                              "pid": 1, "tid": 1, "args": {}}])
    with pytest.raises(ValueError, match="unknown phase"):
        obs.validate_events([{"name": "x", "ph": "B", "ts": 0.0,
                              "pid": 1, "tid": 1}])


# -- recompile accounting -----------------------------------------------------

def test_snapshot_counts_fresh_compile_and_cache_hit():
    @jax.jit
    def f(x):
        return x + 1.0

    snap = jaxhooks.snapshot()
    f(jnp.ones(3))                       # fresh shape → backend compile
    n1, s1 = snap.delta()
    assert n1 >= 1 and s1 > 0
    snap2 = jaxhooks.snapshot()
    f(jnp.ones(3))                       # cache hit → silence
    n2, _ = snap2.delta()
    assert n2 == 0
    snap3 = jaxhooks.snapshot()
    f(jnp.ones(4))                       # new shape → silent-retrace signal
    n3, _ = snap3.delta()
    assert n3 >= 1


def test_measure_surfaces_recompile_in_timed_region():
    @jax.jit
    def f(x):
        return (x * 2.0).sum()

    t = obench.measure(lambda: f(jnp.ones(7)), n=3, warmup=1)
    assert t.n_recompiles == 0           # warmup absorbed the compile
    assert len(t.times) == 3 and t.seconds > 0
    assert t.result is not None
    # arrays precreated so their own fill-kernels compile OUTSIDE the
    # timed region; each f(new shape) then costs exactly one compile
    arrs = iter([jnp.ones(n) for n in (11, 12, 13, 14)])
    t2 = obench.measure(lambda: f(next(arrs)), n=3, warmup=1)
    assert t2.n_recompiles == 3          # every timed call hit a new shape
    row = t2.row()
    assert row["n_recompiles"] == 3 and row["n_timed"] == 3


# -- perf bridge --------------------------------------------------------------

def test_hlo_record_fields():
    @jax.jit
    def f(a, b):
        return (a @ b).sum()

    a = jnp.ones((32, 32))
    rec = perfbridge.hlo_record(f, args=(a, a), measured_s=1e-3)
    assert rec["hlo_flops"] == pytest.approx(2 * 32 ** 3, rel=0.05)
    assert rec["hlo_bytes"] > 0
    assert rec["roofline_fraction"] is not None
    assert 0 < rec["roofline_fraction"]
    assert "n_recompiles" in rec and "roofline" in rec


# -- instrumented subsystems publish; numerics stay bitwise-identical ---------

def _tiny_problem(seed=0):
    from repro.core import ExplicitFleet, PlacementProblem, linear_graph

    rng = np.random.default_rng(seed)
    com = rng.uniform(0.1, 3.0, (5, 5))
    com = (com + com.T) / 2.0
    np.fill_diagonal(com, 0.0)
    g = linear_graph([1.0, 0.8, 1.2, 0.9])
    return PlacementProblem(g, ExplicitFleet(com_cost=com), beta=1.0)


def test_search_metrics_published(telemetry):
    from repro.search import BatchedProblem, random_search

    prob = _tiny_problem()
    eng = BatchedProblem(prob)
    random_search(prob, np.random.default_rng(3), n_candidates=32,
                  engine=eng)
    reg = telemetry
    assert reg.value("search.dispatches") == eng.dispatches
    assert reg.value("search.candidates") >= 32
    assert reg.value("eval.score_grid.dispatches",
                     path="dense") == eng.dispatches
    # every padded shape this run used was a first-seen bucket
    firsts = [r for r in reg.snapshot()
              if r["name"] == "search.bucket_first_dispatch"]
    assert len(firsts) == len(eng._seen_buckets)
    spans = [e for e in obs.trace_events()
             if e["ph"] == "X" and e["name"] == "search.score_batch"]
    assert len(spans) >= 1


def test_enabling_telemetry_never_changes_numerics():
    from repro.search import BatchedProblem, random_search

    def solve():
        prob = _tiny_problem(seed=1)
        eng = BatchedProblem(prob)
        res = random_search(prob, np.random.default_rng(7),
                            n_candidates=48, engine=eng)
        return res, eng.dispatches, eng.evals

    res_off, disp_off, evals_off = solve()
    saved = obs.registry()
    obs.set_registry(obs.MetricsRegistry(enabled=False))
    try:
        with _fresh_trace():
            obs.enable()
            res_on, disp_on, evals_on = solve()
    finally:
        obs.disable()
        obs.set_registry(saved)
    # the hard invariant: identical rng streams, dispatch count, and a
    # BITWISE-equal argmin — instrumentation only reads computed values
    assert disp_on == disp_off and evals_on == evals_off
    np.testing.assert_array_equal(res_on.x, res_off.x)
    assert res_on.F == res_off.F
    assert res_on.dq_fraction == res_off.dq_fraction


# -- histogram quantile export ------------------------------------------------

def test_histogram_quantile_basics():
    """p50/p95/p99 from exponential buckets: estimates land within one
    growth factor of the true quantile, q=0/q=1 hit the exactly-tracked
    min/max, and the estimate is always clamped inside [min, max]."""
    h = obs.Histogram("t", {}, lo=1e-6)
    vals = [0.001 * (i + 1) for i in range(100)]     # 1ms .. 100ms
    for v in vals:
        h.observe(v)
    true = np.quantile(vals, [0.5, 0.95, 0.99])
    for q, want in zip([0.5, 0.95, 0.99], true):
        est = h.quantile(q)
        assert want / h.growth <= est <= want * h.growth
        assert h.min <= est <= h.max
    assert h.quantile(0.0) == h.min
    assert h.quantile(1.0) == h.max
    # monotone in q
    qs = [h.quantile(q) for q in np.linspace(0, 1, 21)]
    assert all(a <= b for a, b in zip(qs, qs[1:]))


def test_histogram_quantile_edge_cases():
    h = obs.Histogram("t", {}, lo=1e-6)
    assert np.isnan(h.quantile(0.5))                 # empty → NaN
    with pytest.raises(ValueError, match="0 <= q <= 1"):
        h.quantile(1.5)
    h.observe(0.25)
    # single observation: every quantile IS that observation (clamping)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.quantile(q) == 0.25
    # underflow bucket: observations at/below lo still answer sanely
    h2 = obs.Histogram("t2", {}, lo=1.0)
    for _ in range(10):
        h2.observe(0.5)
    assert h2.quantile(0.5) == 0.5                   # clamped to min==max
    d = h2.quantiles()
    assert set(d) == {"p50", "p95", "p99"}


def test_histogram_row_exports_quantiles(telemetry):
    hist = telemetry.histogram("q.test", lo=1e-3)
    assert hist.row()["p50"] is None                 # empty export
    for v in (0.1, 0.2, 0.4):
        hist.observe(v)
    row = hist.row()
    assert row["count"] == 3
    for k in ("p50", "p95", "p99"):
        assert row["min"] <= row[k] <= row["max"]
